//! xoshiro256** core generator.

/// xoshiro256** 1.0 — public-domain algorithm by David Blackman and
/// Sebastiano Vigna. 256 bits of state, period 2^256 − 1, passes BigCrush.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 so that small / similar seeds still produce
    /// well-distributed initial states (the reference seeding procedure).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // All-zero state is the one invalid state; SplitMix64 cannot emit
        // four zeros in a row, but guard anyway.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Xoshiro256 { s }
    }

    #[inline]
    /// The next 64 random bits.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seeded(123);
        let mut b = Xoshiro256::seeded(123);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seeded(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_mean_is_half() {
        let mut r = Xoshiro256::seeded(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Xoshiro256::seeded(5);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.next_below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(3);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}
