//! Candidate grids and state-vector assembly.

use super::layout as L;
use crate::cpusim::CpuSpec;
use crate::power::PowerModel;
use crate::sim::Telemetry;

/// One operating point to evaluate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    /// Channel count of the candidate.
    pub channels: f32,
    /// Active cores of the candidate.
    pub cores: f32,
    /// Core frequency of the candidate, GHz.
    pub freq_ghz: f32,
}

/// Model output for one candidate.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prediction {
    /// Application throughput, bytes/s.
    pub tput_bps: f64,
    /// Client package power, W.
    pub power_w: f64,
    /// Projected energy to completion, J.
    pub energy_j: f64,
}

/// Full (cores × P-state) grid at a fixed channel count — what the
/// predictive governor evaluates each timeout. Truncated to the artifact
/// grid size if the CPU is large.
pub fn cpu_grid(spec: &CpuSpec, channels: u32) -> Vec<Candidate> {
    let mut out = Vec::new();
    'outer: for cores in 1..=spec.num_cores {
        for &f in &spec.freq_levels {
            out.push(Candidate {
                channels: channels.max(1) as f32,
                cores: cores as f32,
                freq_ghz: f.as_ghz() as f32,
            });
            if out.len() == L::NUM_CANDIDATES {
                break 'outer;
            }
        }
    }
    out
}

/// Assemble the state vector from interval telemetry + the client's power
/// model (see `layout` for slot semantics).
pub fn build_state(tel: &Telemetry, power: &PowerModel) -> Vec<f32> {
    let spec = &power.spec;
    let mut s = vec![0f32; L::STATE_WIDTH];
    s[L::S_CAPACITY_BPS] = tel.net.available_bps as f32;
    s[L::S_RTT_S] = tel.net.rtt_s as f32;
    s[L::S_AVG_WIN_BYTES] = tel.net.avg_win_bytes as f32;
    s[L::S_KNEE_STREAMS] = tel.net.knee_streams as f32;
    s[L::S_OVERLOAD_GAMMA] = tel.net.overload_gamma as f32;
    s[L::S_OVERLOAD_FLOOR] = tel.net.overload_floor as f32;
    s[L::S_PARALLELISM] = tel.net.parallelism as f32;
    s[L::S_REMAINING_BYTES] = tel.remaining.as_f64() as f32;
    s[L::S_AVG_FILE_BYTES] = tel.net.avg_file_bytes as f32;
    s[L::S_PP_LEVEL] = tel.net.pp_level as f32;
    s[L::S_CYCLES_PER_BYTE] = spec.cycles_per_byte as f32;
    s[L::S_CYCLES_PER_REQ] = spec.cycles_per_request as f32;
    s[L::S_CYCLES_PER_STREAM] = spec.cycles_per_stream_sec as f32;
    s[L::S_MAX_APP_UTIL] = crate::sim::MAX_APP_UTILIZATION as f32;
    s[L::S_PKG_STATIC_W] = power.params.pkg_static_w as f32;
    s[L::S_CORE_IDLE_BASE_W] = power.params.core_idle_base_w as f32;
    s[L::S_CORE_IDLE_PER_GHZ_W] = power.params.core_idle_per_ghz_w as f32;
    s[L::S_DYN_KAPPA] = power.params.dyn_kappa as f32;
    s[L::S_V_MIN] = power.params.v_min as f32;
    s[L::S_V_MAX] = power.params.v_max as f32;
    s[L::S_F_MIN_GHZ] = spec.min_freq().as_ghz() as f32;
    s[L::S_F_MAX_GHZ] = spec.max_freq().as_ghz() as f32;
    s[L::S_DRAM_W_PER_GBS] = power.params.dram_w_per_gbs as f32;
    s
}

/// CloudLab-flavoured demo state, mirroring `model.demo_state()` in
/// Python — shared by unit tests and the parity integration test.
pub fn demo_state() -> Vec<f32> {
    let mut s = vec![0f32; L::STATE_WIDTH];
    s[L::S_CAPACITY_BPS] = 115e6;
    s[L::S_RTT_S] = 0.036;
    s[L::S_AVG_WIN_BYTES] = 1e6;
    s[L::S_KNEE_STREAMS] = 4.5;
    s[L::S_OVERLOAD_GAMMA] = 0.02;
    s[L::S_OVERLOAD_FLOOR] = 0.55;
    s[L::S_PARALLELISM] = 1.0;
    s[L::S_REMAINING_BYTES] = 10e9;
    s[L::S_AVG_FILE_BYTES] = 2.4e6;
    s[L::S_PP_LEVEL] = 2.0;
    s[L::S_CYCLES_PER_BYTE] = 2.2;
    s[L::S_CYCLES_PER_REQ] = 11_000.0;
    s[L::S_CYCLES_PER_STREAM] = 1.4e6;
    s[L::S_MAX_APP_UTIL] = 0.92;
    s[L::S_PKG_STATIC_W] = 10.0;
    s[L::S_CORE_IDLE_BASE_W] = 0.5;
    s[L::S_CORE_IDLE_PER_GHZ_W] = 0.28;
    s[L::S_DYN_KAPPA] = 1.7;
    s[L::S_V_MIN] = 0.65;
    s[L::S_V_MAX] = 1.05;
    s[L::S_F_MIN_GHZ] = 1.2;
    s[L::S_F_MAX_GHZ] = 3.4;
    s[L::S_DRAM_W_PER_GBS] = 2.0;
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpusim::standard::*;

    #[test]
    fn grid_covers_cores_times_freqs() {
        let spec = haswell_server();
        let g = cpu_grid(&spec, 6);
        assert_eq!(g.len(), (spec.num_cores as usize * spec.freq_levels.len()).min(128));
        assert!(g.iter().all(|c| c.channels == 6.0));
        assert!(g.iter().all(|c| c.cores >= 1.0 && c.cores <= 8.0));
    }

    #[test]
    fn grid_truncates_at_artifact_size() {
        let mut spec = broadwell_client();
        spec.num_cores = 64;
        let g = cpu_grid(&spec, 1);
        assert_eq!(g.len(), L::NUM_CANDIDATES);
    }

    #[test]
    fn state_vector_has_layout_width() {
        let tel = crate::sim::Telemetry {
            now: crate::units::SimTime::ZERO,
            avg_throughput: crate::units::Rate::from_mbps(100.0),
            interval_energy: crate::units::Energy::from_joules(1.0),
            avg_power: crate::units::Power::from_watts(20.0),
            cpu_load: 0.5,
            remaining: crate::units::Bytes::from_gb(1.0),
            total: crate::units::Bytes::from_gb(2.0),
            elapsed: crate::units::SimDuration::from_secs(1.0),
            num_channels: 2,
            open_streams: 2,
            net: Default::default(),
        };
        let pm = crate::power::standard_power(&haswell_server());
        let s = build_state(&tel, &pm);
        assert_eq!(s.len(), L::STATE_WIDTH);
        assert_eq!(s[L::S_CYCLES_PER_BYTE], 2.4);
        let spec = haswell_server();
        assert!((s[L::S_F_MAX_GHZ] as f64 - spec.max_freq().as_ghz()).abs() < 1e-6);
    }
}
