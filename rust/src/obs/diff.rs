//! Structural diffing of trace logs and metrics documents
//! (`greendt trace diff A B`).
//!
//! The determinism contract (ARCHITECTURE §Observability) makes traces
//! byte-comparable: one `(config, seed)` produces the same log
//! regardless of `--shards` or wall-clock. This module turns that
//! contract into an A/B tool — diff two runs at the same seed and
//! whatever differs *is* the behavioral change (policy vs policy,
//! `--resilience on` vs `off`, commit vs commit).
//!
//! Records are compared **structurally, not positionally**: each record
//! is canonicalized to its `(kind, name, t0, t1, session, host, attrs)`
//! content — record *ids* and parent links are deliberately excluded,
//! because id sequences shift wholesale when one side emits an extra
//! collector event, and a positional diff would then flag every
//! subsequent record. The comparison is a multiset: records present in
//! both logs cancel, whatever survives is reported per side, plus
//! per-session outcome-tally deltas (the `trace summarize` roll-up) and
//! sessions present on only one side.
//!
//! [`MetricsDiff`] does the same for two `--metrics` JSON documents,
//! excluding the `stepper.*` / `warm_ticks` / `slow_ticks`
//! shard-sensitivity carve-out so that shard-count A/Bs compare clean.
//! [`flatten`] is the shared JSON-walking primitive; the
//! [`crate::benchkit::sentinel`] regression checker reuses it for
//! `BENCH_*.json` comparisons.

use std::collections::BTreeMap;

use crate::history::json::{self, Json};
use crate::metrics::Table;

use super::summarize::TraceLog;
use super::trace::{AttrValue, TraceRecord};

/// Render one attribute value the way the canonical form spells it.
fn attr_text(v: &AttrValue) -> String {
    match v {
        AttrValue::F64(x) => json::num(*x),
        AttrValue::U64(n) => n.to_string(),
        AttrValue::Bool(b) => b.to_string(),
        AttrValue::Str(s) => s.clone(),
    }
}

/// Canonical content form of a record: everything except id/parent.
/// Floats render with shortest-round-trip `Display`, so bit-equal
/// records canonicalize identically and only bit-equal records cancel.
fn canonical(r: &TraceRecord) -> String {
    let mut s = format!(
        "{} {} @{}",
        if r.is_span() { "span" } else { "event" },
        r.name,
        json::num(r.t0_secs)
    );
    if let Some(t1) = r.t1_secs {
        s.push_str(&format!("..{}", json::num(t1)));
    }
    if let Some(sess) = &r.session {
        s.push_str(&format!(" session={sess}"));
    }
    if let Some(host) = &r.host {
        s.push_str(&format!(" host={host}"));
    }
    for (k, v) in &r.attrs {
        s.push_str(&format!(" {k}={}", attr_text(v)));
    }
    s
}

/// One record (multiset) present on only one side of a trace diff.
#[derive(Debug, Clone, PartialEq)]
pub struct RecordDelta {
    /// Session the record is attributed to (`None` for fleet-level
    /// records like cap events and rebalance proposals).
    pub session: Option<String>,
    /// Record name (`admit`, `retry`, `penalty_box`, …).
    pub name: String,
    /// Start/occurrence time, seconds.
    pub t0_secs: f64,
    /// How many copies survive cancellation (usually 1).
    pub count: u64,
    /// The canonical content form (ids/parents excluded).
    pub record: String,
}

/// One per-session outcome-tally field that differs between the sides.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionDelta {
    /// The session.
    pub session: String,
    /// Which tally differs: `spans`, `events`, `residencies`, `moved`,
    /// `joules` or `end`.
    pub field: String,
    /// Side-A value, rendered.
    pub a: String,
    /// Side-B value, rendered.
    pub b: String,
}

/// A structural diff of two trace logs.
#[derive(Debug, Clone, Default)]
pub struct TraceDiff {
    /// Records (after multiset cancellation) present only in side A.
    pub only_in_a: Vec<RecordDelta>,
    /// Records present only in side B.
    pub only_in_b: Vec<RecordDelta>,
    /// Sessions that appear only in side A.
    pub sessions_only_in_a: Vec<String>,
    /// Sessions that appear only in side B.
    pub sessions_only_in_b: Vec<String>,
    /// Outcome-tally fields differing for sessions present in both.
    pub session_deltas: Vec<SessionDelta>,
}

impl TraceDiff {
    /// Diff two parsed logs. Seed-matched identical runs produce an
    /// empty diff (pinned in `rust/tests/calibration_diff.rs`).
    pub fn compute(a: &TraceLog, b: &TraceLog) -> TraceDiff {
        struct Entry {
            ca: u64,
            cb: u64,
            session: Option<String>,
            name: String,
            t0: f64,
        }
        let mut entries: BTreeMap<String, Entry> = BTreeMap::new();
        for (side, log) in [(0, a), (1, b)] {
            for r in &log.records {
                let e = entries.entry(canonical(r)).or_insert_with(|| Entry {
                    ca: 0,
                    cb: 0,
                    session: r.session.clone(),
                    name: r.name.clone(),
                    t0: r.t0_secs,
                });
                if side == 0 {
                    e.ca += 1;
                } else {
                    e.cb += 1;
                }
            }
        }
        let mut diff = TraceDiff::default();
        for (record, e) in &entries {
            let delta = |count: u64| RecordDelta {
                session: e.session.clone(),
                name: e.name.clone(),
                t0_secs: e.t0,
                count,
                record: record.clone(),
            };
            if e.ca > e.cb {
                diff.only_in_a.push(delta(e.ca - e.cb));
            } else if e.cb > e.ca {
                diff.only_in_b.push(delta(e.cb - e.ca));
            }
        }
        let sort = |v: &mut Vec<RecordDelta>| {
            v.sort_by(|x, y| {
                x.t0_secs.total_cmp(&y.t0_secs).then_with(|| x.record.cmp(&y.record))
            })
        };
        sort(&mut diff.only_in_a);
        sort(&mut diff.only_in_b);

        let sa = a.sessions();
        let sb = b.sessions();
        diff.sessions_only_in_a = sa.iter().filter(|s| !sb.contains(s)).cloned().collect();
        diff.sessions_only_in_b = sb.iter().filter(|s| !sa.contains(s)).cloned().collect();
        for s in sa.iter().filter(|s| sb.contains(s)) {
            let (ta, tb) = (a.session_summary(s), b.session_summary(s));
            let mut push = |field: &str, va: String, vb: String| {
                if va != vb {
                    diff.session_deltas.push(SessionDelta {
                        session: s.clone(),
                        field: field.to_string(),
                        a: va,
                        b: vb,
                    });
                }
            };
            push("spans", ta.spans.to_string(), tb.spans.to_string());
            push("events", ta.events.to_string(), tb.events.to_string());
            push("residencies", ta.residencies.to_string(), tb.residencies.to_string());
            push("moved", json::num(ta.moved_bytes), json::num(tb.moved_bytes));
            push("joules", json::num(ta.joules), json::num(tb.joules));
            push("end", ta.end.to_string(), tb.end.to_string());
        }
        diff
    }

    /// True when the logs are structurally identical.
    pub fn is_empty(&self) -> bool {
        self.only_in_a.is_empty()
            && self.only_in_b.is_empty()
            && self.sessions_only_in_a.is_empty()
            && self.sessions_only_in_b.is_empty()
            && self.session_deltas.is_empty()
    }

    /// Sessions implicated by any delta, sorted and deduplicated
    /// (fleet-level records contribute no session).
    pub fn sessions(&self) -> Vec<String> {
        let mut out: Vec<String> = self
            .only_in_a
            .iter()
            .chain(&self.only_in_b)
            .filter_map(|d| d.session.clone())
            .chain(self.sessions_only_in_a.iter().cloned())
            .chain(self.sessions_only_in_b.iter().cloned())
            .chain(self.session_deltas.iter().map(|d| d.session.clone()))
            .collect();
        out.sort();
        out.dedup();
        out
    }

    /// Render the diff as markdown (`labels` name the two sides).
    pub fn to_markdown(&self, label_a: &str, label_b: &str) -> String {
        let mut out = format!("# trace diff: {label_a} vs {label_b}\n\n");
        if self.is_empty() {
            out.push_str("identical (structurally empty diff)\n");
            return out;
        }
        if !self.sessions_only_in_a.is_empty() {
            out.push_str(&format!(
                "sessions only in {label_a}: {}\n",
                self.sessions_only_in_a.join(", ")
            ));
        }
        if !self.sessions_only_in_b.is_empty() {
            out.push_str(&format!(
                "sessions only in {label_b}: {}\n",
                self.sessions_only_in_b.join(", ")
            ));
        }
        if !self.session_deltas.is_empty() {
            let mut t =
                Table::new("session tallies", &["session", "field", label_a, label_b]);
            for d in &self.session_deltas {
                t.push_row(vec![
                    d.session.clone(),
                    d.field.clone(),
                    d.a.clone(),
                    d.b.clone(),
                ]);
            }
            out.push_str(&t.to_markdown());
            out.push('\n');
        }
        let mut side = |label: &str, sign: char, deltas: &[RecordDelta]| {
            if deltas.is_empty() {
                return;
            }
            out.push_str(&format!("## records only in {label} ({})\n\n", deltas.len()));
            const CAP: usize = 200;
            for d in deltas.iter().take(CAP) {
                if d.count > 1 {
                    out.push_str(&format!("{sign} {} (x{})\n", d.record, d.count));
                } else {
                    out.push_str(&format!("{sign} {}\n", d.record));
                }
            }
            if deltas.len() > CAP {
                out.push_str(&format!("… and {} more\n", deltas.len() - CAP));
            }
            out.push('\n');
        };
        side(label_a, '-', &self.only_in_a);
        side(label_b, '+', &self.only_in_b);
        out
    }

    /// Render the diff as one JSON document
    /// (`kind: "greendt-trace-diff"`).
    pub fn to_json(&self, label_a: &str, label_b: &str) -> String {
        let recs = |v: &[RecordDelta]| {
            let rows: Vec<String> = v
                .iter()
                .map(|d| {
                    format!(
                        "{{\"session\":{},\"name\":\"{}\",\"t0\":{},\"count\":{},\
                         \"record\":\"{}\"}}",
                        match &d.session {
                            Some(s) => format!("\"{}\"", json::escape(s)),
                            None => "null".to_string(),
                        },
                        json::escape(&d.name),
                        json::num(d.t0_secs),
                        d.count,
                        json::escape(&d.record)
                    )
                })
                .collect();
            format!("[{}]", rows.join(","))
        };
        let names = |v: &[String]| {
            let rows: Vec<String> =
                v.iter().map(|s| format!("\"{}\"", json::escape(s))).collect();
            format!("[{}]", rows.join(","))
        };
        let deltas: Vec<String> = self
            .session_deltas
            .iter()
            .map(|d| {
                format!(
                    "{{\"session\":\"{}\",\"field\":\"{}\",\"a\":\"{}\",\"b\":\"{}\"}}",
                    json::escape(&d.session),
                    json::escape(&d.field),
                    json::escape(&d.a),
                    json::escape(&d.b)
                )
            })
            .collect();
        format!(
            "{{\"kind\":\"greendt-trace-diff\",\"a\":\"{}\",\"b\":\"{}\",\
             \"identical\":{},\"sessions_only_in_a\":{},\"sessions_only_in_b\":{},\
             \"session_deltas\":[{}],\"only_in_a\":{},\"only_in_b\":{}}}",
            json::escape(label_a),
            json::escape(label_b),
            self.is_empty(),
            names(&self.sessions_only_in_a),
            names(&self.sessions_only_in_b),
            deltas.join(","),
            recs(&self.only_in_a),
            recs(&self.only_in_b)
        )
    }
}

/// Flatten a JSON document to `(dotted.path, leaf)` pairs in
/// deterministic order. Objects contribute `prefix.key` segments;
/// array elements are labeled by their `"name"` member when present
/// (the `BENCH_*.json` micro arrays), by a `h{hosts}s{sessions}x{shards}`
/// label for scale-grid rows, and by index otherwise. Leaves are
/// `Null`/`Bool`/`Num`/`Str` clones.
pub fn flatten(doc: &Json) -> Vec<(String, Json)> {
    fn label(item: &Json, i: usize) -> String {
        if let Some(name) = item.get("name").and_then(Json::as_str) {
            return name.to_string();
        }
        if let (Some(h), Some(s)) = (
            item.get("hosts").and_then(Json::as_u64),
            item.get("sessions").and_then(Json::as_u64),
        ) {
            let x = item.get("shards").and_then(Json::as_u64).unwrap_or(1);
            return format!("h{h}s{s}x{x}");
        }
        i.to_string()
    }
    fn walk(v: &Json, prefix: &str, out: &mut Vec<(String, Json)>) {
        match v {
            Json::Obj(m) => {
                for (k, child) in m {
                    let path =
                        if prefix.is_empty() { k.clone() } else { format!("{prefix}.{k}") };
                    walk(child, &path, out);
                }
            }
            Json::Arr(items) => {
                for (i, child) in items.iter().enumerate() {
                    walk(child, &format!("{prefix}[{}]", label(child, i)), out);
                }
            }
            leaf => out.push((prefix.to_string(), leaf.clone())),
        }
    }
    let mut out = Vec::new();
    walk(doc, "", &mut out);
    out
}

fn leaf_text(v: &Json) -> String {
    match v {
        Json::Null => "null".to_string(),
        Json::Bool(b) => b.to_string(),
        Json::Num(x) => json::num(*x),
        Json::Str(s) => s.clone(),
        _ => "?".to_string(),
    }
}

/// One leaf path differing between two metrics documents.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsDelta {
    /// Dotted leaf path (e.g. `registry.counters.placements.admitted`).
    pub path: String,
    /// Side-A value, rendered (`None` when the path is absent there).
    pub a: Option<String>,
    /// Side-B value, rendered.
    pub b: Option<String>,
}

/// A structural diff of two `--metrics` JSON documents, with the
/// shard-sensitivity carve-out (`stepper.*`, `warm_ticks`,
/// `slow_ticks`) excluded so shard-count A/Bs compare clean.
#[derive(Debug, Clone, Default)]
pub struct MetricsDiff {
    /// Differing leaf paths, in path order.
    pub deltas: Vec<MetricsDelta>,
}

impl MetricsDiff {
    /// True when `path` is in the shard-sensitivity carve-out.
    fn shard_sensitive(path: &str) -> bool {
        path.contains("stepper.")
            || path.ends_with("warm_ticks")
            || path.ends_with("slow_ticks")
    }

    /// Diff two parsed metrics documents.
    pub fn compute(a: &Json, b: &Json) -> MetricsDiff {
        let to_map = |doc: &Json| -> BTreeMap<String, String> {
            flatten(doc)
                .into_iter()
                .filter(|(p, _)| !MetricsDiff::shard_sensitive(p))
                .map(|(p, v)| (p, leaf_text(&v)))
                .collect()
        };
        let (ma, mb) = (to_map(a), to_map(b));
        let mut deltas = Vec::new();
        let mut paths: Vec<&String> = ma.keys().chain(mb.keys()).collect();
        paths.sort();
        paths.dedup();
        for p in paths {
            let (va, vb) = (ma.get(p), mb.get(p));
            if va != vb {
                deltas.push(MetricsDelta {
                    path: p.clone(),
                    a: va.cloned(),
                    b: vb.cloned(),
                });
            }
        }
        MetricsDiff { deltas }
    }

    /// True when the documents agree on every compared leaf.
    pub fn is_empty(&self) -> bool {
        self.deltas.is_empty()
    }

    /// Render as markdown (`labels` name the two sides).
    pub fn to_markdown(&self, label_a: &str, label_b: &str) -> String {
        let mut out = format!("# metrics diff: {label_a} vs {label_b}\n\n");
        if self.is_empty() {
            out.push_str("identical (shard-sensitive series excluded)\n");
            return out;
        }
        let mut t = Table::new("metrics deltas", &["path", label_a, label_b]);
        let cell = |v: &Option<String>| v.clone().unwrap_or_else(|| "(absent)".to_string());
        for d in &self.deltas {
            t.push_row(vec![d.path.clone(), cell(&d.a), cell(&d.b)]);
        }
        out.push_str(&t.to_markdown());
        out
    }

    /// Render as one JSON document (`kind: "greendt-metrics-diff"`).
    pub fn to_json(&self, label_a: &str, label_b: &str) -> String {
        let opt = |v: &Option<String>| match v {
            Some(s) => format!("\"{}\"", json::escape(s)),
            None => "null".to_string(),
        };
        let rows: Vec<String> = self
            .deltas
            .iter()
            .map(|d| {
                format!(
                    "{{\"path\":\"{}\",\"a\":{},\"b\":{}}}",
                    json::escape(&d.path),
                    opt(&d.a),
                    opt(&d.b)
                )
            })
            .collect();
        format!(
            "{{\"kind\":\"greendt-metrics-diff\",\"a\":\"{}\",\"b\":\"{}\",\
             \"identical\":{},\"deltas\":[{}]}}",
            json::escape(label_a),
            json::escape(label_b),
            self.is_empty(),
            rows.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::trace::{trace_jsonl, TraceSink};

    fn sample_sink() -> TraceSink {
        let mut sink = TraceSink::new();
        let root = sink.root("s1", 0.0);
        sink.span(
            "admit",
            0.0,
            20.0,
            Some("s1"),
            Some("h0"),
            Some(root),
            vec![("moved_bytes", 5e8.into()), ("attributed_j", 120.0.into())],
        );
        sink.event("complete", 20.0, Some("s1"), Some("h0"), Some(root), vec![]);
        sink
    }

    #[test]
    fn identical_logs_diff_empty() {
        let a = TraceLog::parse(&trace_jsonl(&sample_sink().finalize(20.0)));
        let b = TraceLog::parse(&trace_jsonl(&sample_sink().finalize(20.0)));
        let d = TraceDiff::compute(&a, &b);
        assert!(d.is_empty(), "{:?}", d);
        assert!(d.to_markdown("a", "b").contains("identical"));
        let j = json::parse(&d.to_json("a", "b")).expect("diff JSON parses");
        assert_eq!(j.get("identical").and_then(Json::as_bool), Some(true));
    }

    #[test]
    fn id_shifts_alone_do_not_diff() {
        let a = TraceLog::parse(&trace_jsonl(&sample_sink().finalize(20.0)));
        // Same content, every id (and parent link) shifted wholesale.
        let mut b = TraceLog::parse(&trace_jsonl(&sample_sink().finalize(20.0)));
        for r in &mut b.records {
            r.id += 100;
            r.parent = r.parent.map(|p| p + 100);
        }
        assert!(TraceDiff::compute(&a, &b).is_empty(), "ids/parents are excluded");
    }

    #[test]
    fn extra_records_localize_to_their_session() {
        let a = TraceLog::parse(&trace_jsonl(&sample_sink().finalize(20.0)));
        let mut sink = sample_sink();
        let root2 = sink.root("s2", 5.0);
        sink.event("retry", 6.0, Some("s2"), None, Some(root2), vec![("attempt", 1u64.into())]);
        let b = TraceLog::parse(&trace_jsonl(&sink.finalize(20.0)));
        let d = TraceDiff::compute(&a, &b);
        assert!(!d.is_empty());
        assert!(d.only_in_a.is_empty());
        assert_eq!(d.sessions_only_in_b, vec!["s2".to_string()]);
        assert!(d.only_in_b.iter().all(|r| r.session.as_deref() == Some("s2")));
        assert_eq!(d.sessions(), vec!["s2".to_string()]);
        let md = d.to_markdown("a", "b");
        assert!(md.contains("+ event retry"), "{md}");
    }

    #[test]
    fn attr_change_shows_on_both_sides() {
        let a = TraceLog::parse(&trace_jsonl(&sample_sink().finalize(20.0)));
        let mut sink = TraceSink::new();
        let root = sink.root("s1", 0.0);
        sink.span(
            "admit",
            0.0,
            20.0,
            Some("s1"),
            Some("h0"),
            Some(root),
            vec![("moved_bytes", 5e8.into()), ("attributed_j", 130.0.into())],
        );
        sink.event("complete", 20.0, Some("s1"), Some("h0"), Some(root), vec![]);
        let b = TraceLog::parse(&trace_jsonl(&sink.finalize(20.0)));
        let d = TraceDiff::compute(&a, &b);
        assert_eq!(d.only_in_a.len(), 1);
        assert_eq!(d.only_in_b.len(), 1);
        assert_eq!(d.only_in_a[0].name, "admit");
        // The tally roll-up localizes the change to the joules column.
        assert!(d.session_deltas.iter().any(|s| s.field == "joules"));
        assert!(d.session_deltas.iter().all(|s| s.session == "s1"));
    }

    #[test]
    fn duplicate_records_cancel_by_count() {
        let mut sink_a = TraceSink::new();
        let root = sink_a.root("s", 0.0);
        for _ in 0..3 {
            sink_a.event("tune", 1.0, Some("s"), Some("h"), Some(root), vec![]);
        }
        let mut sink_b = TraceSink::new();
        let root_b = sink_b.root("s", 0.0);
        sink_b.event("tune", 1.0, Some("s"), Some("h"), Some(root_b), vec![]);
        let a = TraceLog::parse(&trace_jsonl(&sink_a.finalize(2.0)));
        let b = TraceLog::parse(&trace_jsonl(&sink_b.finalize(2.0)));
        let d = TraceDiff::compute(&a, &b);
        assert_eq!(d.only_in_a.len(), 1);
        assert_eq!(d.only_in_a[0].count, 2, "two surplus copies on side A");
        assert!(d.only_in_b.is_empty());
    }

    #[test]
    fn flatten_labels_named_and_grid_rows() {
        let doc = json::parse(
            r#"{"micro":[{"name":"alloc","mean_s":0.5}],
                "grid":[{"hosts":10,"sessions":100,"shards":8,"wall_seconds":2.0}],
                "plain":[1,2]}"#,
        )
        .unwrap();
        let flat = flatten(&doc);
        let paths: Vec<&str> = flat.iter().map(|(p, _)| p.as_str()).collect();
        assert!(paths.contains(&"micro[alloc].mean_s"), "{paths:?}");
        assert!(paths.contains(&"grid[h10s100x8].wall_seconds"));
        assert!(paths.contains(&"plain[0]"));
        assert!(paths.contains(&"micro[alloc].name"), "string leaves kept");
    }

    #[test]
    fn metrics_diff_excludes_shard_carveout() {
        let a = json::parse(
            r#"{"registry":{"counters":{"placements.admitted":2,"stepper.warm_ticks":100}},
                "timeline":[{"t":3,"warm_ticks":50,"slow_ticks":1,"watts":40}]}"#,
        )
        .unwrap();
        let b = json::parse(
            r#"{"registry":{"counters":{"placements.admitted":2,"stepper.warm_ticks":999}},
                "timeline":[{"t":3,"warm_ticks":2,"slow_ticks":9,"watts":40}]}"#,
        )
        .unwrap();
        assert!(MetricsDiff::compute(&a, &b).is_empty(), "only carve-out series differ");
        let c = json::parse(
            r#"{"registry":{"counters":{"placements.admitted":3}},"timeline":[]}"#,
        )
        .unwrap();
        let d = MetricsDiff::compute(&a, &c);
        assert!(!d.is_empty());
        assert!(d
            .deltas
            .iter()
            .any(|x| x.path == "registry.counters.placements.admitted"));
        assert!(json::parse(&d.to_json("a", "c")).is_some());
        assert!(d.to_markdown("a", "c").contains("placements.admitted"));
    }
}
