//! Background cross-traffic process.
//!
//! Real WAN paths (Chameleon/CloudLab share their links with other tenants)
//! have slowly varying residual capacity. We model the *fraction* of the
//! bottleneck consumed by cross traffic as a mean-reverting
//! (Ornstein-Uhlenbeck-style) process, clamped to [0, max_fraction],
//! plus optional scripted step events so experiments can inject bandwidth
//! drops deterministically (used by the Warning/Recovery tests and the
//! `adaptive_bandwidth` example).

use crate::rng::Xoshiro256;
use crate::units::{SimDuration, SimTime};

/// A scripted change to the background-traffic mean at a given time.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BandwidthEvent {
    /// When the event takes effect.
    pub at: SimTime,
    /// New mean background fraction in [0, 1) from that time on.
    pub mean_fraction: f64,
}

/// Mean-reverting background-traffic fraction.
#[derive(Debug, Clone)]
pub struct BackgroundTraffic {
    /// Long-run mean fraction of the bottleneck used by cross traffic.
    mean: f64,
    /// Reversion rate (1/s). Larger = faster return to the mean.
    theta: f64,
    /// Diffusion strength (fraction / sqrt(s)).
    sigma: f64,
    /// Hard cap on the fraction (never starve the transfer entirely).
    max_fraction: f64,
    /// Current value.
    value: f64,
    /// Scripted events, sorted by time; consumed as the clock passes them.
    events: Vec<BandwidthEvent>,
    next_event: usize,
}

impl BackgroundTraffic {
    /// A quiet path: small mean load, gentle variation.
    pub fn quiet(mean: f64) -> Self {
        Self::new(mean, 0.5, 0.02, 0.85)
    }

    /// A completely deterministic, constant background (for unit tests).
    pub fn constant(fraction: f64) -> Self {
        Self::new(fraction, 0.0, 0.0, 0.95)
    }

    /// A process with explicit OU parameters (mean level, reversion rate `theta`, noise `sigma`, hard ceiling).
    pub fn new(mean: f64, theta: f64, sigma: f64, max_fraction: f64) -> Self {
        assert!((0.0..1.0).contains(&mean), "mean fraction must be in [0,1)");
        BackgroundTraffic {
            mean,
            theta,
            sigma,
            max_fraction,
            value: mean,
            events: Vec::new(),
            next_event: 0,
        }
    }

    /// Register scripted events. The list may arrive in any order —
    /// callers assemble it from several sources (fault schedules, CLI
    /// scripts) — so it is validated and sorted here; an unsorted list
    /// must never make [`Self::next_event_at`] skip a later-listed
    /// earlier event. The sort is stable and by time only, so events
    /// sharing a timestamp keep their listed order and the *last listed*
    /// wins when both apply on the same tick.
    pub fn with_events(mut self, mut events: Vec<BandwidthEvent>) -> Self {
        for e in &events {
            let at = e.at.as_secs();
            assert!(
                at.is_finite() && at >= 0.0,
                "bandwidth event time {at} must be finite and >= 0"
            );
            assert!(
                e.mean_fraction.is_finite() && (0.0..=1.0).contains(&e.mean_fraction),
                "bandwidth event fraction {} must be in [0, 1]",
                e.mean_fraction
            );
        }
        // `total_cmp`, not `partial_cmp().unwrap()`: the times are
        // finite by the assert above, but the ordering must not be able
        // to panic on data it has already accepted.
        events.sort_by(|a, b| a.at.as_secs().total_cmp(&b.at.as_secs()));
        self.events = events;
        self
    }

    /// Current fraction of the bottleneck taken by cross traffic.
    pub fn fraction(&self) -> f64 {
        self.value
    }

    /// True when a [`Self::tick`] with no scripted event due is a state
    /// no-op — bit-for-bit: no noise is drawn (`sigma == 0`), the drift
    /// term is exactly zero (`theta == 0`, or the value already sits at
    /// the mean), the clamp is the identity (value within bounds), and
    /// adding the zero drift does not renormalize the value's sign bit.
    /// The warm-epoch batched stepper may skip link ticks only while
    /// this holds; see ARCHITECTURE.md §Scale.
    pub fn is_frozen(&self) -> bool {
        self.sigma == 0.0
            && (self.theta == 0.0 || self.value == self.mean)
            && (0.0..=self.max_fraction).contains(&self.value)
            && (self.value + 0.0).to_bits() == self.value.to_bits()
    }

    /// When the next scripted event fires (`None` once all are consumed).
    /// Events apply on the first tick whose start time reaches this
    /// instant, so a batched stepper must fall back to the real
    /// [`Self::tick`] for any tick with `next_event_at() <= now`.
    pub fn next_event_at(&self) -> Option<SimTime> {
        self.events.get(self.next_event).map(|e| e.at)
    }

    /// Advance the process by `dt`.
    pub fn tick(&mut self, now: SimTime, dt: SimDuration, rng: &mut Xoshiro256) {
        // Apply any scripted events whose time has come.
        while self.next_event < self.events.len() && self.events[self.next_event].at <= now {
            self.mean = self.events[self.next_event].mean_fraction.clamp(0.0, self.max_fraction);
            // Step events move the value immediately: a new flow starting is
            // abrupt at WAN timescales.
            self.value = self.mean;
            self.next_event += 1;
        }

        let dt_s = dt.as_secs();
        if dt_s <= 0.0 {
            return;
        }
        // Euler-Maruyama step of dX = theta (mu - X) dt + sigma dW.
        let noise = if self.sigma > 0.0 {
            // Polar method inline to avoid importing Normal (hot path).
            let z;
            loop {
                let u = 2.0 * rng.next_f64() - 1.0;
                let v = 2.0 * rng.next_f64() - 1.0;
                let s = u * u + v * v;
                if s > 0.0 && s < 1.0 {
                    z = u * (-2.0 * s.ln() / s).sqrt();
                    break;
                }
            }
            self.sigma * dt_s.sqrt() * z
        } else {
            0.0
        };
        self.value += self.theta * (self.mean - self.value) * dt_s + noise;
        self.value = self.value.clamp(0.0, self.max_fraction);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_stays_constant() {
        let mut bg = BackgroundTraffic::constant(0.2);
        let mut rng = Xoshiro256::seeded(1);
        let mut t = SimTime::ZERO;
        for _ in 0..100 {
            bg.tick(t, SimDuration::from_millis(100.0), &mut rng);
            t += SimDuration::from_millis(100.0);
            assert_eq!(bg.fraction(), 0.2);
        }
    }

    #[test]
    fn reverts_to_mean() {
        let mut bg = BackgroundTraffic::new(0.3, 2.0, 0.0, 0.9);
        bg.value = 0.8;
        let mut rng = Xoshiro256::seeded(2);
        let mut t = SimTime::ZERO;
        for _ in 0..200 {
            bg.tick(t, SimDuration::from_millis(100.0), &mut rng);
            t += SimDuration::from_millis(100.0);
        }
        assert!((bg.fraction() - 0.3).abs() < 0.02, "value {}", bg.fraction());
    }

    #[test]
    fn stays_in_bounds_under_noise() {
        let mut bg = BackgroundTraffic::new(0.1, 0.5, 0.2, 0.85);
        let mut rng = Xoshiro256::seeded(3);
        let mut t = SimTime::ZERO;
        for _ in 0..5000 {
            bg.tick(t, SimDuration::from_millis(100.0), &mut rng);
            t += SimDuration::from_millis(100.0);
            assert!((0.0..=0.85).contains(&bg.fraction()));
        }
    }

    #[test]
    fn scripted_event_applies_at_time() {
        let mut bg = BackgroundTraffic::constant(0.0).with_events(vec![BandwidthEvent {
            at: SimTime::from_secs(5.0),
            mean_fraction: 0.5,
        }]);
        let mut rng = Xoshiro256::seeded(4);
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        for _ in 0..200 {
            bg.tick(t, dt, &mut rng);
            t += dt;
        }
        assert_eq!(bg.fraction(), 0.5);
    }

    #[test]
    fn events_sorted_even_if_pushed_unsorted() {
        let bg = BackgroundTraffic::constant(0.0).with_events(vec![
            BandwidthEvent { at: SimTime::from_secs(10.0), mean_fraction: 0.2 },
            BandwidthEvent { at: SimTime::from_secs(5.0), mean_fraction: 0.4 },
        ]);
        assert!(bg.events[0].at < bg.events[1].at);
    }

    #[test]
    fn unsorted_and_duplicate_events_apply_in_time_order() {
        // Regression: an unsorted list must not let `next_event_at` (and
        // the apply loop) skip a later-listed earlier event, and
        // duplicate timestamps must resolve deterministically — stable
        // sort keeps the listed order, the apply loop consumes both, so
        // the last-listed value is in force.
        let mut bg = BackgroundTraffic::constant(0.0).with_events(vec![
            BandwidthEvent { at: SimTime::from_secs(10.0), mean_fraction: 0.2 },
            BandwidthEvent { at: SimTime::from_secs(5.0), mean_fraction: 0.4 },
            BandwidthEvent { at: SimTime::from_secs(5.0), mean_fraction: 0.1 },
        ]);
        assert_eq!(bg.next_event_at(), Some(SimTime::from_secs(5.0)));
        let mut rng = Xoshiro256::seeded(9);
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        for _ in 0..60 {
            bg.tick(t, dt, &mut rng);
            t += dt;
        }
        // Past t = 5 s: both duplicates consumed, last listed in force.
        assert_eq!(bg.fraction(), 0.1);
        assert_eq!(bg.next_event_at(), Some(SimTime::from_secs(10.0)));
        for _ in 0..60 {
            bg.tick(t, dt, &mut rng);
            t += dt;
        }
        assert_eq!(bg.fraction(), 0.2);
        assert_eq!(bg.next_event_at(), None);
    }

    #[test]
    #[should_panic(expected = "must be finite")]
    fn nan_event_time_is_rejected_loudly() {
        // `partial_cmp().unwrap()` used to panic opaquely mid-sort on a
        // NaN timestamp; construction now rejects it with a message.
        let _ = BackgroundTraffic::constant(0.0).with_events(vec![BandwidthEvent {
            at: SimTime::from_secs(f64::NAN),
            mean_fraction: 0.2,
        }]);
    }

    #[test]
    #[should_panic(expected = "must be in [0, 1]")]
    fn out_of_range_event_fraction_is_rejected() {
        let _ = BackgroundTraffic::constant(0.0).with_events(vec![BandwidthEvent {
            at: SimTime::from_secs(1.0),
            mean_fraction: 1.5,
        }]);
    }
}
