//! Fleet tracing: reconstruct one session's story from the span log.
//!
//!     cargo run --release --example fleet_trace
//!
//! The scenario stacks the two nastiest fleet events on one run: a
//! 1 W power-cap squeeze (t = 10 s → 120 s) that blocks every
//! admission while it holds, and a host death at t = 40 s (revived at
//! t = 150 s) that kills the first session mid-flight. Recovery is on,
//! so the victim waits out its PenaltyBox backoff, queues against the
//! cap, and is re-admitted elsewhere once the cap lifts.
//!
//! The run records lifecycle spans and decision events (`--trace` in
//! CLI terms) plus the metrics registry (`--metrics`). Afterwards the
//! example replays the trace the way `greendt trace` does: per-session
//! rollup, span-duration percentiles, and the reconstructed waterfall
//! of the retried session — admit residency, fault, penalty box,
//! queued placement, redelivery — as one connected tree.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::obs::{trace_jsonl, TraceLog};
use greendt::resilience::{FaultSchedule, ResilienceConfig};
use greendt::sim::dispatcher::{run_dispatcher, DispatcherConfig, HostSpec, SessionSpec};
use greendt::units::{Power, SimTime};

fn main() {
    println!("== fleet_trace: cap squeeze + host death, replayed from spans ==\n");

    let hosts = vec![
        HostSpec::new("alpha-cloudlab", testbeds::cloudlab()).with_max_sessions(2),
        HostSpec::new("beta-didclab", testbeds::didclab()).with_max_sessions(2),
    ];
    let sessions = vec![
        SessionSpec::new("victim", standard::medium_dataset(11), AlgorithmKind::MaxThroughput),
        SessionSpec::new("steady", standard::medium_dataset(12), AlgorithmKind::MinEnergy)
            .arriving_at(SimTime::from_secs(5.0)),
        SessionSpec::new("latecomer", standard::medium_dataset(13), AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(15.0)),
    ];
    let faults = FaultSchedule::default().with_host_failure(
        0,
        SimTime::from_secs(40.0),
        Some(SimTime::from_secs(150.0)),
    );
    let cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(42)
        .with_cap_event(SimTime::from_secs(10.0), Some(Power::from_watts(1.0)))
        .with_cap_event(SimTime::from_secs(120.0), None)
        .with_resilience(ResilienceConfig::new().with_recovery().with_faults(faults))
        .with_trace()
        .with_metrics();
    let out = run_dispatcher(&cfg);
    assert!(out.fleet.completed, "every session must be delivered in the end");

    // Replay the trace exactly the way `greendt trace summarize` does.
    let jsonl = trace_jsonl(out.trace.as_ref().expect("tracing was on"));
    let log = TraceLog::parse(&jsonl);
    println!("{} trace records ({} sessions)\n", log.records.len(), log.sessions().len());
    println!("{}", log.summary_table().to_markdown());
    println!("{}", log.histogram_table().to_markdown());

    // The retried session's waterfall: one connected tree from admission
    // through fault, penalty box and redelivery to completion.
    let retried = out
        .retries
        .first()
        .map(|r| r.session.clone())
        .expect("the host death must schedule a retry");
    let tree = log.tree(&retried);
    println!(
        "waterfall for '{retried}' ({}):\n",
        if tree.connected() { "connected" } else { "DISCONNECTED" }
    );
    print!("{}", tree.waterfall());

    // A few registry figures the CLI would print from --metrics.
    let m = out.metrics.as_ref().expect("metrics were on");
    println!("\nregistry highlights:");
    for c in ["placements.admitted", "placements.queued", "faults.fired", "retries.scheduled"] {
        println!("  {c:<22} {}", m.registry.counter(c));
    }
    if let Some(h) = m.registry.histogram("queue.wait_s") {
        println!(
            "  queue.wait_s           n={} p50={:.1}s p95={:.1}s (the cap squeeze, visible)",
            h.count(),
            h.percentile(0.50).unwrap_or(0.0),
            h.percentile(0.95).unwrap_or(0.0)
        );
    }
    if let Some(rate) = m.warm_hit_rate() {
        println!("  stepper warm-batch hit rate: {:.1}%", rate * 100.0);
    }
    println!(
        "\nevery figure above was reconstructed from the span log alone — the same\n\
         bytes `greendt fleet --trace` writes and `greendt trace` renders."
    );
}
