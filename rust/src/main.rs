//! GreenDT leader binary: CLI entry point.

use greendt::cli;

fn main() {
    cli::init_logger();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match cli::run(&argv) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
    }
}
