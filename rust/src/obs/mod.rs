//! Deterministic, zero-dependency observability: lifecycle spans,
//! decision events, counters and percentile histograms (ISSUE 9).
//!
//! The paper's algorithms live on runtime measurements — throughput
//! deltas, power draw, tuning reactions per monitoring interval — yet
//! until this subsystem the reproduction only reported end-of-run
//! aggregates. `obs` adds the missing substrate in three pieces:
//!
//! * **[`trace`]** — sim-clock spans (`session` → `admit` residencies,
//!   `slow_start`, `migrate`, `penalty_box`) and instant decision events
//!   (`tune`, `placement`/`placement_score`, `rebalance_proposal`
//!   including rejected candidates, `cap_event`, `fault`, `retry`,
//!   `complete`/`dead_letter`) with parent links, versioned JSONL
//!   serialization and a Chrome `trace_event` export for Perfetto;
//! * **[`metrics`]** — counters, gauges and exact-percentile log2-bucket
//!   histograms, snapshotted per dispatcher segment into a
//!   [`MetricsTimeline`];
//! * **[`summarize`]** — the read side: parse a trace back, rebuild
//!   per-session span trees, check connectivity, render waterfalls and
//!   histogram tables (the `greendt trace` CLI).
//!
//! The governing constraint is *determinism preservation*: tracing off
//! is bit-identical to an untraced run (every hook is a pure read behind
//! an `Option`), and trace bytes are bit-identical across `--shards`
//! 1/2/8 (emission only at segment boundaries, per-host buffers merged
//! in host-index order — the PR-6 lockstep discipline). The one
//! deliberately shard-*sensitive* series, warm/slow stepper occupancy,
//! lives in metrics only — see [`metrics`]'s module docs. Pinned by
//! `rust/tests/trace_determinism.rs`.

pub mod metrics;
pub mod summarize;
pub mod trace;

pub use metrics::{
    FleetMetrics, Histogram, MetricsRegistry, MetricsTimeline, SegmentSnapshot,
    METRICS_FORMAT_VERSION,
};
pub use summarize::{SessionTree, TraceLog};
pub use trace::{
    chrome_trace_json, trace_jsonl, AttrValue, TraceBuf, TraceRecord, TraceSink,
    TRACE_FORMAT_VERSION,
};
