//! Result tables with markdown and CSV rendering.

use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// A simple column-aligned result table.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Table caption.
    pub title: String,
    /// Column headers.
    pub headers: Vec<String>,
    /// Row cells (outer = rows).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given caption and headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Table {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row (must match the header count).
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as aligned markdown (also pleasant on a terminal).
    pub fn to_markdown(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "### {}", self.title);
            let _ = writeln!(out);
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:width$} |", cells[i], width = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{:-<width$}|", "", width = w + 2);
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        let _ = writeln!(out, "{}", self.headers.iter().map(esc).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(esc).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write the CSV form to `path` (creating parent directories).
    pub fn save_csv(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
        std::fs::write(path, self.to_csv())
            .with_context(|| format!("writing {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("demo", &["name", "value"]);
        t.push_row(vec!["alpha".into(), "1".into()]);
        t.push_row(vec!["b,c".into(), "2".into()]);
        t
    }

    #[test]
    fn markdown_is_aligned() {
        let md = table().to_markdown();
        assert!(md.contains("### demo"));
        assert!(md.contains("| name  | value |"));
        assert!(md.contains("| alpha | 1     |"));
    }

    #[test]
    fn csv_quotes_commas() {
        let csv = table().to_csv();
        assert!(csv.starts_with("name,value\n"));
        assert!(csv.contains("\"b,c\",2"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push_row(vec!["only-one".into()]);
    }

    #[test]
    fn save_csv_roundtrip() {
        let dir = std::env::temp_dir().join("greendt_table_test");
        let path = dir.join("t.csv");
        table().save_csv(&path).unwrap();
        let read = std::fs::read_to_string(&path).unwrap();
        assert_eq!(read, table().to_csv());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
