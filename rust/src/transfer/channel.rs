//! A transfer channel: one concurrent file slot with its TCP streams.

use crate::netsim::StreamState;
use crate::units::Bytes;

/// One channel = one concurrently transferred file (the unit of
/// *concurrency*), carried by `parallelism` TCP streams (chunks of the
/// file in flight at once).
#[derive(Debug, Clone)]
pub struct Channel {
    /// Index of the partition this channel serves.
    pub partition: usize,
    /// One TCP congestion state per parallel stream.
    pub streams: Vec<StreamState>,
}

impl Channel {
    /// Open a new (cold) channel: all streams start in slow start.
    pub fn open(partition: usize, parallelism: u32, avg_win: Bytes) -> Self {
        let streams =
            (0..parallelism.max(1)).map(|_| StreamState::new(avg_win)).collect();
        Channel { partition, streams }
    }

    /// Open a channel whose streams are already at steady state (used by
    /// tests and by baselines that model long-lived sessions).
    pub fn open_warm(partition: usize, parallelism: u32, avg_win: Bytes) -> Self {
        let streams =
            (0..parallelism.max(1)).map(|_| StreamState::warm(avg_win)).collect();
        Channel { partition, streams }
    }

    /// Open TCP streams on this channel.
    pub fn num_streams(&self) -> usize {
        self.streams.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn open_creates_parallel_streams() {
        let c = Channel::open(0, 4, Bytes::from_mb(1.0));
        assert_eq!(c.num_streams(), 4);
        assert!(c.streams.iter().all(|s| s.in_slow_start()));
    }

    #[test]
    fn parallelism_floors_at_one() {
        let c = Channel::open(0, 0, Bytes::from_mb(1.0));
        assert_eq!(c.num_streams(), 1);
    }

    #[test]
    fn warm_channels_skip_slow_start() {
        let c = Channel::open_warm(1, 2, Bytes::from_mb(1.0));
        assert!(c.streams.iter().all(|s| !s.in_slow_start()));
    }
}
