//! Spans, instant events and the deterministic trace collector.
//!
//! The trace model is deliberately small: one record type,
//! [`TraceRecord`], is either a *span* (a closed `[t0, t1]` interval on
//! the simulated clock) or an *instant event* (`t1` absent). Records
//! carry structured attributes and a `parent` link, so a session's
//! causal path — `session` root → `admit` residencies → `tune` /
//! `complete` events, with `migrate` / `penalty_box` spans between
//! residencies — reconstructs as a tree (see [`super::summarize`]).
//!
//! **Determinism contract.** Record ids are `(track, seq)` pairs packed
//! into a `u64` ([`TraceBuf::next_id`]): the dispatcher/collector owns
//! track 0, host *i* owns track *i + 1*. Every emitter allocates ids in
//! its own deterministic program order, emission only ever happens at
//! segment boundaries (never inside the tick loop), and the dispatcher
//! drains per-host buffers in host-index order — so the merged log is
//! byte-identical across `--shards` counts and across repeated runs of
//! one `(config, seed)`. [`TraceSink::finalize`] sorts the merged log by
//! `(t0, id)` with a total order (`f64::total_cmp`), which is itself
//! insensitive to merge arrival order.
//!
//! Serialization is versioned JSONL through the same hand-rolled codec
//! the history store uses ([`crate::history::json`]); a Chrome
//! `trace_event` export ([`chrome_trace_json`]) loads directly into
//! Perfetto / `chrome://tracing`.

use std::collections::BTreeMap;

use crate::history::json::{self, Json};

/// Version written into every trace line (`"v"`); bump on schema change.
pub const TRACE_FORMAT_VERSION: u32 = 1;

/// One structured attribute value.
#[derive(Debug, Clone, PartialEq)]
pub enum AttrValue {
    /// A float (serialized with shortest-round-trip `Display`).
    F64(f64),
    /// An unsigned integer (counts, attempts).
    U64(u64),
    /// A boolean.
    Bool(bool),
    /// A string (labels, verdicts).
    Str(String),
}

impl AttrValue {
    fn to_json(&self) -> String {
        match self {
            AttrValue::F64(x) => json::num(*x),
            AttrValue::U64(n) => format!("{n}"),
            AttrValue::Bool(b) => format!("{b}"),
            AttrValue::Str(s) => format!("\"{}\"", json::escape(s)),
        }
    }

    fn from_json(v: &Json) -> Option<AttrValue> {
        match v {
            Json::Num(x) => Some(AttrValue::F64(*x)),
            Json::Bool(b) => Some(AttrValue::Bool(*b)),
            Json::Str(s) => Some(AttrValue::Str(s.clone())),
            _ => None,
        }
    }

    /// The value as a float (integers widen; `None` for bool/str).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            AttrValue::F64(x) => Some(*x),
            AttrValue::U64(n) => Some(*n as f64),
            _ => None,
        }
    }

    /// The value as a string slice, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            AttrValue::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }
}

impl From<f64> for AttrValue {
    fn from(x: f64) -> AttrValue {
        AttrValue::F64(x)
    }
}

impl From<u64> for AttrValue {
    fn from(n: u64) -> AttrValue {
        AttrValue::U64(n)
    }
}

impl From<u32> for AttrValue {
    fn from(n: u32) -> AttrValue {
        AttrValue::U64(n as u64)
    }
}

impl From<bool> for AttrValue {
    fn from(b: bool) -> AttrValue {
        AttrValue::Bool(b)
    }
}

impl From<&str> for AttrValue {
    fn from(s: &str) -> AttrValue {
        AttrValue::Str(s.to_string())
    }
}

impl From<String> for AttrValue {
    fn from(s: String) -> AttrValue {
        AttrValue::Str(s)
    }
}

/// One span (closed interval) or instant event (`t1_secs` absent).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceRecord {
    /// Deterministic id: `((track + 1) << 32) | seq` (see [`TraceBuf`]).
    pub id: u64,
    /// Parent record id, `None` for roots and free-standing events.
    pub parent: Option<u64>,
    /// Taxonomy label (`"session"`, `"admit"`, `"tune"`, `"migrate"`, …).
    pub name: String,
    /// Start (spans) or occurrence (events) on the simulated clock.
    pub t0_secs: f64,
    /// End of a span; `None` marks an instant event.
    pub t1_secs: Option<f64>,
    /// Session/tenant this record belongs to, when any.
    pub session: Option<String>,
    /// Host name the record is attributed to, when any.
    pub host: Option<String>,
    /// Structured attributes, serialized in insertion order.
    pub attrs: Vec<(String, AttrValue)>,
}

impl TraceRecord {
    /// True for closed-interval spans (`t1_secs` present).
    pub fn is_span(&self) -> bool {
        self.t1_secs.is_some()
    }

    /// Span duration in seconds (`None` for instant events).
    pub fn duration_secs(&self) -> Option<f64> {
        self.t1_secs.map(|t1| t1 - self.t0_secs)
    }

    /// Attribute lookup.
    pub fn attr(&self, key: &str) -> Option<&AttrValue> {
        self.attrs.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    /// Numeric attribute lookup (integers widen to `f64`).
    pub fn attr_f64(&self, key: &str) -> Option<f64> {
        self.attr(key).and_then(AttrValue::as_f64)
    }

    /// String attribute lookup.
    pub fn attr_str(&self, key: &str) -> Option<&str> {
        self.attr(key).and_then(AttrValue::as_str)
    }

    /// One versioned JSONL line (fixed key order, deterministic bytes).
    pub fn to_json_line(&self) -> String {
        let mut out = format!(
            "{{\"v\":{},\"kind\":\"{}\",\"id\":{},\"name\":\"{}\",\"t0\":{}",
            TRACE_FORMAT_VERSION,
            if self.is_span() { "span" } else { "event" },
            self.id,
            json::escape(&self.name),
            json::num(self.t0_secs),
        );
        if let Some(t1) = self.t1_secs {
            out.push_str(&format!(",\"t1\":{}", json::num(t1)));
        }
        if let Some(p) = self.parent {
            out.push_str(&format!(",\"parent\":{p}"));
        }
        if let Some(s) = &self.session {
            out.push_str(&format!(",\"session\":\"{}\"", json::escape(s)));
        }
        if let Some(h) = &self.host {
            out.push_str(&format!(",\"host\":\"{}\"", json::escape(h)));
        }
        if !self.attrs.is_empty() {
            out.push_str(",\"attrs\":{");
            for (i, (k, v)) in self.attrs.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("\"{}\":{}", json::escape(k), v.to_json()));
            }
            out.push('}');
        }
        out.push('}');
        out
    }

    /// Parse one line back (any supported version). Numeric attributes
    /// come back as [`AttrValue::F64`] — JSON does not distinguish
    /// integer widths. Returns `None` for unknown versions or shapes.
    pub fn from_json(v: &Json) -> Option<TraceRecord> {
        let version = v.get("v").and_then(Json::as_u32)?;
        if version == 0 || version > TRACE_FORMAT_VERSION {
            return None;
        }
        let kind = v.get("kind").and_then(Json::as_str)?;
        let t1_secs = match kind {
            "span" => Some(v.get("t1").and_then(Json::as_f64)?),
            "event" => None,
            _ => return None,
        };
        let mut attrs = Vec::new();
        if let Some(Json::Obj(m)) = v.get("attrs") {
            for (k, av) in m {
                attrs.push((k.clone(), AttrValue::from_json(av)?));
            }
        }
        Some(TraceRecord {
            id: v.get("id").and_then(Json::as_u64)?,
            parent: v.get("parent").and_then(Json::as_u64),
            name: v.get("name").and_then(Json::as_str)?.to_string(),
            t0_secs: v.get("t0").and_then(Json::as_f64)?,
            t1_secs,
            session: v.get("session").and_then(Json::as_str).map(str::to_string),
            host: v.get("host").and_then(Json::as_str).map(str::to_string),
            attrs,
        })
    }
}

/// A per-emitter record buffer with deterministic id allocation.
///
/// Each emitter (the dispatcher's collector, each `HostWorld`) owns one
/// buffer with a unique track number; ids are allocated in emission
/// order within the track, so the id stream is a pure function of that
/// emitter's deterministic program order — independent of thread
/// scheduling and shard count.
#[derive(Debug, Clone)]
pub struct TraceBuf {
    track: u64,
    seq: u64,
    records: Vec<TraceRecord>,
}

impl TraceBuf {
    /// A fresh buffer owning `track` (0 = dispatcher, host *i* = *i*+1).
    pub fn new(track: u64) -> TraceBuf {
        TraceBuf { track, seq: 0, records: Vec::new() }
    }

    /// The track this buffer allocates ids on.
    pub fn track(&self) -> u64 {
        self.track
    }

    /// Allocate the next record id on this track.
    pub fn next_id(&mut self) -> u64 {
        self.seq += 1;
        ((self.track + 1) << 32) | self.seq
    }

    /// Append an instant event; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn event(
        &mut self,
        name: &str,
        t_secs: f64,
        session: Option<&str>,
        host: Option<&str>,
        parent: Option<u64>,
        attrs: Vec<(&str, AttrValue)>,
    ) -> u64 {
        let id = self.next_id();
        self.records.push(TraceRecord {
            id,
            parent,
            name: name.to_string(),
            t0_secs: t_secs,
            t1_secs: None,
            session: session.map(str::to_string),
            host: host.map(str::to_string),
            attrs: attrs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        });
        id
    }

    /// Append a closed span; returns its id. Pass `id` to close a span
    /// whose id was pre-allocated with [`Self::next_id`] (residency
    /// spans hand their id to children before they close).
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &mut self,
        id: Option<u64>,
        name: &str,
        t0_secs: f64,
        t1_secs: f64,
        session: Option<&str>,
        host: Option<&str>,
        parent: Option<u64>,
        attrs: Vec<(&str, AttrValue)>,
    ) -> u64 {
        let id = id.unwrap_or_else(|| self.next_id());
        self.records.push(TraceRecord {
            id,
            parent,
            name: name.to_string(),
            t0_secs,
            t1_secs: Some(t1_secs),
            session: session.map(str::to_string),
            host: host.map(str::to_string),
            attrs: attrs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        });
        id
    }

    /// Take the buffered records (id allocation state is kept).
    pub fn drain(&mut self) -> Vec<TraceRecord> {
        std::mem::take(&mut self.records)
    }
}

/// The dispatcher-side collector: owns track 0, allocates session root
/// spans, merges per-host buffers, and finalizes the log.
#[derive(Debug, Clone)]
pub struct TraceSink {
    buf: TraceBuf,
    /// Session name → root span id (one root per session for its whole
    /// life, across residencies, retries and migrations).
    roots: BTreeMap<String, u64>,
    /// Root span open time, keyed like `roots`.
    root_t0: BTreeMap<String, f64>,
    records: Vec<TraceRecord>,
}

impl Default for TraceSink {
    fn default() -> TraceSink {
        TraceSink::new()
    }
}

impl TraceSink {
    /// An empty collector.
    pub fn new() -> TraceSink {
        TraceSink {
            buf: TraceBuf::new(0),
            roots: BTreeMap::new(),
            root_t0: BTreeMap::new(),
            records: Vec::new(),
        }
    }

    /// The root span id for `session`, created at `t_secs` on first use.
    pub fn root(&mut self, session: &str, t_secs: f64) -> u64 {
        if let Some(id) = self.roots.get(session) {
            return *id;
        }
        let id = self.buf.next_id();
        self.roots.insert(session.to_string(), id);
        self.root_t0.insert(session.to_string(), t_secs);
        id
    }

    /// The root span id for `session`, if one exists already.
    pub fn root_of(&self, session: &str) -> Option<u64> {
        self.roots.get(session).copied()
    }

    /// Emit a collector-side instant event; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn event(
        &mut self,
        name: &str,
        t_secs: f64,
        session: Option<&str>,
        host: Option<&str>,
        parent: Option<u64>,
        attrs: Vec<(&str, AttrValue)>,
    ) -> u64 {
        let id = self.buf.event(name, t_secs, session, host, parent, attrs);
        self.records.append(&mut self.buf.drain());
        id
    }

    /// Emit a collector-side closed span; returns its id.
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &mut self,
        name: &str,
        t0_secs: f64,
        t1_secs: f64,
        session: Option<&str>,
        host: Option<&str>,
        parent: Option<u64>,
        attrs: Vec<(&str, AttrValue)>,
    ) -> u64 {
        let id = self.buf.span(None, name, t0_secs, t1_secs, session, host, parent, attrs);
        self.records.append(&mut self.buf.drain());
        id
    }

    /// Merge a host buffer's drained records (call in host-index order
    /// at each segment boundary — the merge discipline that keeps the
    /// log shard-invariant).
    pub fn absorb(&mut self, mut records: Vec<TraceRecord>) {
        self.records.append(&mut records);
    }

    /// Close every session root (a root ends at its last record, or at
    /// `end_secs` for sessions with none) and return the full log sorted
    /// by `(t0, id)` under a total order.
    pub fn finalize(mut self, end_secs: f64) -> Vec<TraceRecord> {
        // Last activity per session, from the merged children.
        let mut last: BTreeMap<String, f64> = BTreeMap::new();
        for r in &self.records {
            if let Some(s) = &r.session {
                let t = r.t1_secs.unwrap_or(r.t0_secs);
                let e = last.entry(s.clone()).or_insert(t);
                if t > *e {
                    *e = t;
                }
            }
        }
        for (session, id) in std::mem::take(&mut self.roots) {
            let t0 = self.root_t0.get(&session).copied().unwrap_or(0.0);
            let t1 = last.get(&session).copied().unwrap_or(end_secs).max(t0);
            self.records.push(TraceRecord {
                id,
                parent: None,
                name: "session".to_string(),
                t0_secs: t0,
                t1_secs: Some(t1),
                session: Some(session),
                host: None,
                attrs: Vec::new(),
            });
        }
        self.records
            .sort_by(|a, b| a.t0_secs.total_cmp(&b.t0_secs).then(a.id.cmp(&b.id)));
        self.records
    }
}

/// Render a record list as versioned JSONL (one record per line,
/// trailing newline, deterministic bytes).
pub fn trace_jsonl(records: &[TraceRecord]) -> String {
    let mut out = String::new();
    for r in records {
        out.push_str(&r.to_json_line());
        out.push('\n');
    }
    out
}

/// Render a record list in Chrome `trace_event` format (a JSON array of
/// `"X"` complete events and `"i"` instants), loadable in Perfetto or
/// `chrome://tracing`. Timestamps are simulated microseconds; `pid` is
/// always 1 and `tid` is the emitter track (0 = dispatcher, host *i* =
/// *i* + 1), so each host renders as its own row.
pub fn chrome_trace_json(records: &[TraceRecord]) -> String {
    let mut events = Vec::with_capacity(records.len());
    for r in records {
        let tid = (r.id >> 32).saturating_sub(1);
        let mut args = String::new();
        if let Some(s) = &r.session {
            args.push_str(&format!(",\"session\":\"{}\"", json::escape(s)));
        }
        if let Some(h) = &r.host {
            args.push_str(&format!(",\"host\":\"{}\"", json::escape(h)));
        }
        for (k, v) in &r.attrs {
            args.push_str(&format!(",\"{}\":{}", json::escape(k), v.to_json()));
        }
        let args = if args.is_empty() {
            "{}".to_string()
        } else {
            format!("{{{}}}", &args[1..])
        };
        let common = format!(
            "\"name\":\"{}\",\"pid\":1,\"tid\":{},\"ts\":{},\"args\":{}",
            json::escape(&r.name),
            tid,
            json::num(r.t0_secs * 1e6),
            args
        );
        match r.t1_secs {
            Some(t1) => events.push(format!(
                "{{{common},\"ph\":\"X\",\"dur\":{}}}",
                json::num((t1 - r.t0_secs) * 1e6)
            )),
            None => events.push(format!("{{{common},\"ph\":\"i\",\"s\":\"t\"}}")),
        }
    }
    format!("[\n{}\n]\n", events.join(",\n"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TraceRecord {
        TraceRecord {
            id: (2 << 32) | 7,
            parent: Some(1 << 32),
            name: "admit".to_string(),
            t0_secs: 1.5,
            t1_secs: Some(4.25),
            session: Some("s1".to_string()),
            host: Some("h0".to_string()),
            attrs: vec![
                ("moved_bytes".to_string(), AttrValue::F64(1e9)),
                ("attempt".to_string(), AttrValue::U64(2)),
                ("end".to_string(), AttrValue::Str("complete".to_string())),
                ("halved".to_string(), AttrValue::Bool(false)),
            ],
        }
    }

    #[test]
    fn jsonl_round_trips() {
        let r = sample();
        let line = r.to_json_line();
        let v = json::parse(&line).expect("line parses");
        let back = TraceRecord::from_json(&v).expect("record parses");
        assert_eq!(back.id, r.id);
        assert_eq!(back.parent, r.parent);
        assert_eq!(back.name, r.name);
        assert_eq!(back.t0_secs.to_bits(), r.t0_secs.to_bits());
        assert_eq!(back.t1_secs.map(f64::to_bits), r.t1_secs.map(f64::to_bits));
        assert_eq!(back.session, r.session);
        assert_eq!(back.attr_f64("moved_bytes"), Some(1e9));
        assert_eq!(back.attr_f64("attempt"), Some(2.0));
        assert_eq!(back.attr_str("end"), Some("complete"));
    }

    #[test]
    fn events_have_no_t1() {
        let mut buf = TraceBuf::new(3);
        let id = buf.event("tune", 9.0, Some("s"), None, None, vec![("ch", 4u32.into())]);
        let recs = buf.drain();
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].id, id);
        assert!(!recs[0].is_span());
        assert!(!recs[0].to_json_line().contains("\"t1\""));
        assert!(recs[0].to_json_line().contains("\"kind\":\"event\""));
    }

    #[test]
    fn ids_encode_track_and_order() {
        let mut buf = TraceBuf::new(0);
        let a = buf.next_id();
        let b = buf.next_id();
        assert_eq!(a, (1 << 32) | 1);
        assert_eq!(b, (1 << 32) | 2);
        let mut host = TraceBuf::new(1);
        assert_eq!(host.next_id(), (2 << 32) | 1);
    }

    #[test]
    fn sink_roots_are_stable_per_session() {
        let mut sink = TraceSink::new();
        let a = sink.root("s1", 0.0);
        let b = sink.root("s1", 99.0);
        assert_eq!(a, b, "one root per session for its whole life");
        assert_ne!(sink.root("s2", 1.0), a);
        assert_eq!(sink.root_of("s1"), Some(a));
        assert_eq!(sink.root_of("nope"), None);
    }

    #[test]
    fn finalize_closes_roots_at_last_activity_and_sorts() {
        let mut sink = TraceSink::new();
        let root = sink.root("s1", 2.0);
        sink.event("tune", 10.0, Some("s1"), None, Some(root), vec![]);
        sink.span("admit", 2.0, 30.0, Some("s1"), Some("h"), Some(root), vec![]);
        let recs = sink.finalize(99.0);
        let session = recs.iter().find(|r| r.name == "session").unwrap();
        assert_eq!(session.id, root);
        assert_eq!(session.t0_secs, 2.0);
        assert_eq!(session.t1_secs, Some(30.0), "ends at the last child, not the run end");
        // Sorted by (t0, id).
        for w in recs.windows(2) {
            assert!(
                (w[0].t0_secs, w[0].id) <= (w[1].t0_secs, w[1].id),
                "unsorted: {w:?}"
            );
        }
    }

    #[test]
    fn finalize_without_children_uses_run_end() {
        let mut sink = TraceSink::new();
        sink.root("ghost", 5.0);
        let recs = sink.finalize(50.0);
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].t1_secs, Some(50.0));
    }

    #[test]
    fn chrome_export_is_valid_json_with_span_and_instant() {
        let mut sink = TraceSink::new();
        let root = sink.root("s1", 0.0);
        sink.event("retry", 3.0, Some("s1"), Some("h0"), Some(root), vec![
            ("attempt", 1u64.into()),
        ]);
        sink.span("admit", 0.0, 8.0, Some("s1"), Some("h0"), Some(root), vec![]);
        let recs = sink.finalize(8.0);
        let chrome = chrome_trace_json(&recs);
        let v = json::parse(&chrome).expect("chrome export parses as JSON");
        let arr = v.as_arr().expect("an array of events");
        assert_eq!(arr.len(), 3);
        let phases: Vec<&str> =
            arr.iter().filter_map(|e| e.get("ph").and_then(Json::as_str)).collect();
        assert_eq!(phases.iter().filter(|p| **p == "X").count(), 2);
        assert_eq!(phases.iter().filter(|p| **p == "i").count(), 1);
        // µs timestamps.
        let spans: Vec<f64> =
            arr.iter().filter_map(|e| e.get("dur").and_then(Json::as_f64)).collect();
        assert!(spans.contains(&8e6));
    }

    #[test]
    fn jsonl_renderer_is_one_line_per_record() {
        let mut sink = TraceSink::new();
        sink.root("s", 0.0);
        sink.event("cap_event", 1.0, None, None, None, vec![("cap_w", 40.0.into())]);
        let recs = sink.finalize(2.0);
        let text = trace_jsonl(&recs);
        assert_eq!(text.lines().count(), 2);
        for line in text.lines() {
            assert!(json::parse(line).is_some(), "unparseable line: {line}");
        }
    }
}
