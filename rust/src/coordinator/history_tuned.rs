//! History-warm-started tuning: skip the probe when the fleet has
//! already seen this workload.
//!
//! [`HistoryTuned`] is the "apply" layer of the historical-log subsystem
//! ([`crate::history`]): given a [`WarmStart`] answered by the k-NN index
//! (the settled `(cores, P-state, channels)` point of the most similar
//! past runs), the session starts *there* — channels open at the
//! converged count with no Slow Start correction phase, and the client
//! CPU begins at the recorded operating point instead of Algorithm 1's
//! cold minimum. Everything after t = 0 is the paper's machinery
//! unchanged — and structurally so: `HistoryTuned` is a thin shell
//! around an embedded [`MinEnergy`] whose every timeout it forwards, so
//! the steady-state loop cannot drift from Algorithm 4's. Warm mode only
//! rewrites the *initial conditions*
//! ([`MinEnergy::skip_slow_start`] plus the warm CPU point in the plan);
//! a stale warm start is therefore corrected at runtime rather than
//! trusted forever.
//!
//! Without a warm start (empty store, or confidence below
//! [`crate::history::CONFIDENCE_FLOOR`] — the caller decides by passing
//! `None`), nothing is overridden at all and the session is bit-for-bit
//! the existing ME slow-start path (pinned by
//! `rust/tests/history_learning.rs`).
//!
//! **Fleet-mode scope.** On a policy-managed host (`greendt fleet`, the
//! dispatcher) the [`FleetPolicy`](crate::coordinator::fleet::FleetPolicy)
//! owns the real CPU knobs and per-session governors actuate a shadow
//! setting, so the warm `(cores, P-state)` is inert there — only the
//! warm *channel count* takes effect (skipping the slow-start probe).
//! The full operating point applies in single-session mode
//! (`greendt run --history`), where the session owns the host CPU.
//! Warm-starting the policy's own host knobs from aggregate history is
//! a ROADMAP follow-on.

use super::algorithm::{Algorithm, InitPlan};
use super::min_energy::MinEnergy;
use crate::config::experiment::{GovernorKind, TunerParams};
use crate::config::Testbed;
use crate::cpusim::CpuState;
use crate::dataset::Dataset;
use crate::history::WarmStart;
use crate::sim::{Telemetry, TuneCtx};
use crate::units::SimDuration;

/// The history-warm-started Minimum Energy algorithm (see the module
/// docs). Cold (`warm == None`) it *is* [`MinEnergy`].
#[derive(Debug)]
pub struct HistoryTuned {
    params: TunerParams,
    warm: Option<WarmStart>,
    /// The real machinery, warm or cold: a complete ME instance every
    /// call is forwarded to.
    inner: MinEnergy,
}

impl HistoryTuned {
    /// A session warm-started from `warm` (or the plain ME cold path when
    /// `None`).
    pub fn new(params: TunerParams, warm: Option<WarmStart>) -> Self {
        HistoryTuned { inner: MinEnergy::new(params), params, warm }
    }

    /// The warm start in effect (`None` = cold fallback).
    pub fn warm_start(&self) -> Option<WarmStart> {
        self.warm
    }
}

impl Algorithm for HistoryTuned {
    fn name(&self) -> &'static str {
        "HistoryTuned"
    }

    fn timeout(&self) -> SimDuration {
        self.inner.timeout()
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        // Algorithm 1 runs either way — history replaces the *probed*
        // knobs (channels, CPU point), not the dataset layout.
        let plan = self.inner.init(testbed, dataset);
        let Some(warm) = self.warm else { return plan };

        let spec = testbed.client_cpu.clone();
        let pstate = (warm.pstate as usize).min(spec.freq_levels.len() - 1);
        let freq = spec.freq_levels[pstate];
        let cores = warm.cores.clamp(1, spec.num_cores);
        // Same OS-governor escape hatch as ME: without the load-control
        // module the OS owns the CPU and the warm point applies to
        // channels only.
        let client_cpu = if self.params.governor == GovernorKind::Os {
            CpuState::performance(spec)
        } else {
            CpuState::new(spec, cores, freq)
        };
        let num_ch = warm.channels.clamp(1, self.params.max_ch);
        self.inner.skip_slow_start(num_ch);
        InitPlan::new(plan.partitions, num_ch, client_cpu)
    }

    fn fsm_label(&self) -> &'static str {
        self.inner.fsm_label()
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        self.inner.on_timeout(telemetry, ctx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    #[test]
    fn cold_init_matches_min_energy_exactly() {
        let params = TunerParams::default();
        let tb = testbeds::didclab();
        let ds = standard::medium_dataset(5);
        let mut warmless = HistoryTuned::new(params, None);
        let mut me = MinEnergy::new(params);
        let a = warmless.init(&tb, &ds);
        let b = me.init(&tb, &ds);
        assert_eq!(a.num_channels, b.num_channels);
        assert_eq!(a.client_cpu.active_cores(), b.client_cpu.active_cores());
        assert_eq!(a.client_cpu.freq(), b.client_cpu.freq());
        assert_eq!(a.partitions.len(), b.partitions.len());
        assert_eq!(warmless.fsm_label(), "slow-start");
        assert!(warmless.warm_start().is_none());
    }

    #[test]
    fn warm_init_starts_at_the_recorded_point() {
        let tb = testbeds::didclab();
        let warm = WarmStart { cores: 2, pstate: 1, channels: 9 };
        let mut ht = HistoryTuned::new(TunerParams::default(), Some(warm));
        let plan = ht.init(&tb, &standard::medium_dataset(5));
        assert_eq!(plan.num_channels, 9, "channels open at the converged count");
        assert_eq!(plan.client_cpu.active_cores(), 2);
        assert_eq!(plan.client_cpu.freq(), tb.client_cpu.freq_levels[1]);
        // No slow-start phase: the FSM starts in Increase.
        assert_eq!(ht.fsm_label(), "increase");
        assert_eq!(ht.warm_start(), Some(warm));
    }

    #[test]
    fn warm_init_clamps_out_of_range_points() {
        // A record from a bigger machine must not panic on this one.
        let tb = testbeds::cloudlab();
        let warm = WarmStart { cores: 999, pstate: 999, channels: 999 };
        let mut ht = HistoryTuned::new(TunerParams::default(), Some(warm));
        let plan = ht.init(&tb, &standard::small_dataset(1));
        assert_eq!(plan.client_cpu.active_cores(), tb.client_cpu.num_cores);
        assert_eq!(plan.client_cpu.freq(), tb.client_cpu.max_freq());
        assert_eq!(plan.num_channels, TunerParams::default().max_ch);
    }

    #[test]
    fn warm_session_completes_and_keeps_adapting() {
        use crate::coordinator::AlgorithmKind;
        use crate::sim::session::{run_session, SessionConfig};
        let warm = WarmStart { cores: 2, pstate: 1, channels: 9 };
        let cfg = SessionConfig::new(
            testbeds::didclab(),
            standard::medium_dataset(6),
            AlgorithmKind::HistoryTuned(Some(warm)),
        )
        .with_seed(77);
        let out = run_session(&cfg);
        assert!(out.completed, "warm session must finish");
        assert_eq!(out.algorithm, "HistoryTuned");
        assert!(out.avg_throughput.as_mbps() > 100.0);
        // Runtime adaptation stayed on: the FSM may move channels past
        // the warm point, and the governor owns the CPU afterward.
        assert!(out.peak_channels >= 9);
    }
}
