//! PJRT runtime: load and execute AOT-compiled XLA artifacts from Rust.
//!
//! The Python layers (JAX model + Pallas kernel) are lowered once at build
//! time to HLO **text** (`make artifacts`); this module loads that text,
//! compiles it on the PJRT CPU client, and executes it on the
//! coordinator's decision path. Python never runs at transfer time.
//!
//! HLO text — not a serialized `HloModuleProto` — is the interchange
//! format: jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! The PJRT client itself needs the external `xla` crate, which the
//! offline build does not vendor, so it is gated behind the **`pjrt`**
//! feature (see `Cargo.toml`). Without it, [`Executable::load_hlo_text`]
//! returns an error and every consumer falls back to the bit-compatible
//! Rust oracle ([`crate::predictor::Backend::Oracle`]) — the default
//! build stays fully functional.

use anyhow::Result;

/// A dense f32 tensor with row-major shape, the runtime's argument type.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayF32 {
    /// Row-major dimensions.
    pub shape: Vec<usize>,
    /// The flattened elements.
    pub data: Vec<f32>,
}

impl ArrayF32 {
    /// A tensor with the given shape; checks the element count.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let expect: usize = shape.iter().product();
        anyhow::ensure!(
            expect == data.len(),
            "shape {:?} wants {} elements, got {}",
            shape,
            expect,
            data.len()
        );
        Ok(ArrayF32 { shape, data })
    }

    /// A rank-1 tensor.
    pub fn vector(data: Vec<f32>) -> Self {
        ArrayF32 { shape: vec![data.len()], data }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True for an empty tensor.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }
}

#[cfg(feature = "pjrt")]
mod backend {
    use super::ArrayF32;
    use anyhow::{Context, Result};
    use std::path::Path;

    /// Thread-local PJRT CPU client: the `xla` crate's client is `Rc`-based
    /// (not `Send`), so each thread owns one. Creation is cheap next to
    /// compilation, and executables compile once per thread per artifact
    /// (see [`with_compiled`]).
    fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T>) -> Result<T> {
        thread_local! {
            static CLIENT: once_cell::unsync::OnceCell<xla::PjRtClient> =
                const { once_cell::unsync::OnceCell::new() };
        }
        CLIENT.with(|cell| {
            let client = cell.get_or_try_init(|| {
                xla::PjRtClient::cpu().context("creating PJRT CPU client")
            })?;
            f(client)
        })
    }

    /// Parse the HLO-text artifact at `path` and compile it on this
    /// thread's PJRT client.
    fn compile(path: &str) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text at {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        with_client(|client| {
            client.compile(&comp).with_context(|| format!("compiling {path}"))
        })
    }

    /// Run `f` against the compiled executable for `path`, compiling it
    /// into this thread's cache on first use. Loaded executables are
    /// `Rc`-based like the client, so they can never cross threads; the
    /// cache gives every thread its own copy, keyed by artifact path.
    fn with_compiled<T>(
        path: &str,
        f: impl FnOnce(&xla::PjRtLoadedExecutable) -> Result<T>,
    ) -> Result<T> {
        use std::cell::RefCell;
        use std::collections::HashMap;
        use std::rc::Rc;
        thread_local! {
            static CACHE: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>> =
                RefCell::new(HashMap::new());
        }
        let exe = CACHE.with(|cache| -> Result<Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = cache.borrow().get(path) {
                return Ok(Rc::clone(exe));
            }
            // Compile outside the borrow: `compile` may itself take the
            // thread-local client, and a panic mid-borrow would poison
            // every later lookup on this thread.
            let exe = Rc::new(compile(path)?);
            cache.borrow_mut().insert(path.to_string(), Rc::clone(&exe));
            Ok(exe)
        })?;
        f(&exe)
    }

    /// Handle to an AOT-compiled XLA artifact.
    ///
    /// The handle holds only the artifact *path*: the compiled (non-
    /// `Send`) PJRT object lives in a per-thread cache, so the handle is
    /// `Send` and a governor carrying one migrates freely across the
    /// sharded dispatcher's worker threads. Each thread that actually
    /// executes it compiles its own copy on first use (compilation is
    /// deterministic, so every copy computes identical results).
    #[derive(Debug, Clone)]
    pub struct Executable {
        path: String,
    }

    impl Executable {
        /// Load HLO text from `path` and compile it on the CPU client.
        /// Compilation is eager so a bad artifact fails here — at load
        /// time — not at the first mid-run execution; it also warms the
        /// calling thread's cache.
        pub fn load_hlo_text(path: impl AsRef<Path>) -> Result<Self> {
            let path = path
                .as_ref()
                .to_str()
                .context("non-UTF8 artifact path")?
                .to_string();
            with_compiled(&path, |_| Ok(()))?;
            Ok(Executable { path })
        }

        /// Execute with f32 inputs; returns the elements of the output tuple
        /// as flat f32 buffers (jax lowers with `return_tuple=True`).
        pub fn run_f32(&self, inputs: &[ArrayF32]) -> Result<Vec<Vec<f32>>> {
            let mut literals = Vec::with_capacity(inputs.len());
            for a in inputs {
                let shape: Vec<i64> = a.shape.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(&a.data)
                    .reshape(&shape)
                    .with_context(|| format!("reshaping input to {:?}", a.shape))?;
                literals.push(lit);
            }
            with_compiled(&self.path, |exe| {
                let result = exe
                    .execute::<xla::Literal>(&literals)
                    .with_context(|| format!("executing {}", self.path))?;
                let out =
                    result[0][0].to_literal_sync().context("fetching result buffer")?;
                // Unpack the tuple: jax's return_tuple=True wraps outputs.
                let elements = out.to_tuple().context("untupling result")?;
                let mut vecs = Vec::with_capacity(elements.len());
                for e in elements {
                    vecs.push(e.to_vec::<f32>().context("reading f32 output")?);
                }
                Ok(vecs)
            })
        }

        /// Path the executable was loaded from.
        pub fn path(&self) -> &str {
            &self.path
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    use super::ArrayF32;
    use anyhow::{bail, Result};
    use std::path::Path;

    /// Stub standing in for the PJRT executable when the `pjrt` feature is
    /// off. Loading always fails, so no instance can exist; consumers take
    /// their oracle fallback path.
    #[derive(Debug)]
    pub struct Executable {
        path: String,
        /// Uninhabited so the stub can never be constructed.
        never: std::convert::Infallible,
    }

    impl Executable {
        /// Always fails without the `pjrt` feature.
        pub fn load_hlo_text(path: impl AsRef<Path>) -> Result<Self> {
            bail!(
                "built without the `pjrt` feature: cannot load {} (the \
                 predictor falls back to the pure-Rust oracle)",
                path.as_ref().display()
            )
        }

        /// Unreachable: the stub cannot be constructed.
        pub fn run_f32(&self, _inputs: &[ArrayF32]) -> Result<Vec<Vec<f32>>> {
            match self.never {}
        }

        /// Path the executable would have been loaded from.
        pub fn path(&self) -> &str {
            &self.path
        }
    }
}

pub use backend::Executable;

/// Default artifact location, overridable with `GREENDT_PREDICTOR`.
pub fn default_predictor_path() -> String {
    std::env::var("GREENDT_PREDICTOR").unwrap_or_else(|_| "artifacts/predictor.hlo.txt".into())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn array_shape_validation() {
        assert!(ArrayF32::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(ArrayF32::new(vec![2, 3], vec![0.0; 5]).is_err());
        let v = ArrayF32::vector(vec![1.0, 2.0]);
        assert_eq!(v.shape, vec![2]);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let r = Executable::load_hlo_text("/nonexistent/predictor.hlo.txt");
        assert!(r.is_err());
    }

    // Artifact-backed execution is covered by the integration test
    // `rust/tests/predictor_parity.rs` (requires `make artifacts` and a
    // build with `--features pjrt`).
}
