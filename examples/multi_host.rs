//! Multi-host dispatcher: a heterogeneous two-host fleet serving an open
//! Poisson workload under the three placement policies.
//!
//!     cargo run --release --example multi_host
//!
//! An efficient Broadwell client (CloudLab) sits next to a legacy
//! Bloomfield one (DIDCLab). `roundrobin` ignores the difference,
//! `leastloaded` balances occupancy, and `marginalenergy` scores each
//! candidate host by the predicted delta in whole-host power per byte of
//! expected goodput (GreenDataFlow, arXiv:1810.05892) — routing work to
//! the machine that moves it cheapest. The figures of merit are fleet
//! energy, aggregate goodput and the Jain fairness index.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::metrics::Table;
use greendt::sim::dispatcher::{
    run_dispatcher, DispatchOutcome, DispatcherConfig, HostSpec, PoissonArrivals,
};

fn run_placement(placement: PlacementKind) -> DispatchOutcome {
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()),
        HostSpec::new("legacy", testbeds::didclab()),
    ];
    // ~1 session every 2 minutes, 6 sessions — light enough that the
    // efficient host could serve everything, heavy enough to overlap.
    let sessions = PoissonArrivals::new(1.0 / 120.0, 6, 42)
        .sessions("medium", AlgorithmKind::MaxThroughput)
        .expect("medium is a standard family");
    let cfg = DispatcherConfig::new(hosts, placement)
        .with_sessions(sessions)
        .with_seed(42);
    run_dispatcher(&cfg)
}

fn main() {
    println!("== multi_host: 2 heterogeneous hosts, 6 Poisson sessions ==\n");

    let mut table = Table::new(
        "placement policies compared",
        &["placement", "fleet energy", "makespan", "agg goodput", "jain", "on legacy"],
    );
    for placement in [
        PlacementKind::RoundRobin,
        PlacementKind::LeastLoaded,
        PlacementKind::MarginalEnergy,
    ] {
        let out = run_placement(placement);
        let fleet = &out.fleet;
        assert!(fleet.completed, "{} run did not finish", placement.id());
        let legacy = fleet.tenants.iter().filter(|t| t.host == "legacy").count();
        let goodput = greendt::units::Rate::average(fleet.moved, fleet.duration);
        table.push_row(vec![
            placement.id().to_string(),
            format!("{}", fleet.client_energy),
            format!("{}", fleet.duration),
            format!("{}", goodput),
            format!("{:.3}", fleet.jain_fairness()),
            format!("{legacy}/6"),
        ]);

        if placement == PlacementKind::MarginalEnergy {
            println!("marginal-energy decisions:");
            for d in &out.decisions {
                let host = d.host.clone().unwrap_or_else(|| "queued".into());
                let best = d
                    .scores
                    .iter()
                    .map(|s| format!("{}: {:.2e} J/B", s.host, s.marginal_j_per_byte))
                    .collect::<Vec<_>>()
                    .join(", ");
                println!(
                    "  t={:>6.1}s  {} -> {}  ({}; fleet projection {:.1} W)",
                    d.t_secs, d.session, host, best, d.projected_fleet_power_w
                );
            }
            println!();
        }
    }
    println!("{}", table.to_markdown());
    println!(
        "marginal-energy placement routes sessions to the host whose operating\n\
         point moves their bytes for the fewest joules; with headroom on the\n\
         efficient machine the legacy host only ever burns idle power."
    );
}
