//! Typed experiment configuration.

use crate::coordinator::load_control::LoadThresholds;
use crate::units::SimDuration;

/// Which CPU-scaling policy a tuning algorithm runs with.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GovernorKind {
    /// No application-level scaling: the OS `ondemand` default applies
    /// (baselines; Figure 4 "w/o scaling" ablation).
    Os,
    /// Algorithm 3 thresholds (the paper's policy; default).
    Threshold,
    /// Candidate-grid energy model compiled from JAX/Pallas, executed via
    /// PJRT (GreenDT extension; see `predictor`).
    Predictive,
    /// No governor at all — not even the OS default. Used by the fleet
    /// driver, where a [`crate::coordinator::fleet::FleetPolicy`] owns the
    /// host CPU knobs and per-session governors must not fight it.
    None,
}

/// Knobs shared by the three tuning algorithms (Algorithms 4–6).
#[derive(Debug, Clone, Copy)]
pub struct TunerParams {
    /// Negative-feedback band (the paper's α).
    pub alpha: f64,
    /// Positive-feedback band (the paper's β).
    pub beta: f64,
    /// Channel step ΔCh.
    pub delta_ch: u32,
    /// EETT's channel step: one channel is the rate quantum it controls
    /// in, so a finer step keeps it inside the SLA band (§IV-C).
    pub target_delta_ch: u32,
    /// Hard channel cap (`maxCh`).
    pub max_ch: u32,
    /// Tuning timeout for ME/EEMT.
    pub timeout: SimDuration,
    /// EETT uses a shorter timeout ("faster reaction time", §IV-C).
    pub target_timeout: SimDuration,
    /// Slow-start correction rounds.
    pub slow_start_rounds: u32,
    /// Algorithm 3 thresholds.
    pub thresholds: LoadThresholds,
    /// CPU-scaling policy.
    pub governor: GovernorKind,
}

impl Default for TunerParams {
    fn default() -> Self {
        TunerParams {
            alpha: 0.10,
            beta: 0.05,
            delta_ch: 2,
            target_delta_ch: 1,
            max_ch: 48,
            timeout: SimDuration::from_secs(3.0),
            target_timeout: SimDuration::from_secs(1.0),
            slow_start_rounds: 2,
            thresholds: LoadThresholds::default(),
            governor: GovernorKind::Threshold,
        }
    }
}

impl TunerParams {
    /// The Figure 4 ablation: identical tuner, application CPU scaling
    /// removed (the OS ondemand default applies).
    pub fn without_scaling(mut self) -> Self {
        self.governor = GovernorKind::Os;
        self
    }

    /// Use the PJRT-compiled predictive governor.
    pub fn predictive(mut self) -> Self {
        self.governor = GovernorKind::Predictive;
        self
    }
}

/// A fully specified experiment run (one session).
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Testbed name (see [`crate::config::testbeds::by_name`]).
    pub testbed: String,
    /// Dataset family name (see [`crate::dataset::standard::by_name`]).
    pub dataset: String,
    /// Algorithm identifier (see [`crate::coordinator::AlgorithmKind::parse`]).
    pub algorithm: String,
    /// Optional target rate in Mbps (EETT / Ismail-TT).
    pub target_mbps: Option<f64>,
    /// RNG seed.
    pub seed: u64,
    /// Simulation tick.
    pub tick: SimDuration,
    /// Give up after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Tuner knobs.
    pub tuner: TunerParams,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            testbed: "cloudlab".into(),
            dataset: "medium".into(),
            algorithm: "eemt".into(),
            target_mbps: None,
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            tuner: TunerParams::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let p = TunerParams::default();
        assert!(p.alpha > 0.0 && p.beta > 0.0);
        assert!(p.max_ch > p.delta_ch);
        assert!(p.target_timeout < p.timeout);
        assert_eq!(p.governor, GovernorKind::Threshold);
    }

    #[test]
    fn without_scaling_flips_governor_only() {
        let p = TunerParams::default().without_scaling();
        assert_eq!(p.governor, GovernorKind::Os);
        assert_eq!(p.alpha, TunerParams::default().alpha);
    }

    #[test]
    fn experiment_default_has_long_deadline() {
        let e = ExperimentConfig::default();
        assert!(e.max_sim_time.as_secs() >= 3600.0);
    }
}
