//! wget, curl and http/2.0 baseline models.
//!
//! §V-A: "Wget and curl perform very poorly due to the lack of any
//! optimization ... http/2.0 achieves better performance thanks to
//! multiplexing, which reduces the impact of RTTs, especially when
//! transferring small files. However, on a wide area network, http/2.0 is
//! not able to fully use the bandwidth due to the lack of parallelism and
//! concurrency tuning."

use crate::config::Testbed;
use crate::coordinator::algorithm::{Algorithm, InitPlan};
use crate::coordinator::load_control::{Governor, OndemandGovernor};
use crate::cpusim::CpuState;
use crate::dataset::{Dataset, Partition};
use crate::sim::{Telemetry, TuneCtx};
use crate::units::{Bytes, SimDuration};

/// Effectively infinite pipelining: HTTP/2 multiplexes all requests on one
/// connection, so per-file RTTs vanish.
const HTTP2_MULTIPLEX_DEPTH: u32 = 10_000;

/// A non-tuning, single-connection transfer tool.
#[derive(Debug)]
pub struct SimpleTool {
    name: &'static str,
    /// Pipelining depth of the single connection.
    pp_level: u32,
    /// Extra RTTs charged per file (fresh TCP + sequential request).
    handshake_rtts: f64,
    /// The OS default frequency governor (no tool controls the CPU).
    governor: OndemandGovernor,
}

impl SimpleTool {
    /// wget: new TCP connection per file, fully sequential requests —
    /// 2 extra RTTs per file on top of the un-pipelined request RTT.
    pub fn wget() -> Self {
        SimpleTool { name: "wget", pp_level: 1, handshake_rtts: 2.0, governor: OndemandGovernor::default() }
    }

    /// curl (with keep-alive): one persistent connection, but still one
    /// sequential request-response per file.
    pub fn curl() -> Self {
        SimpleTool { name: "curl", pp_level: 1, handshake_rtts: 0.0, governor: OndemandGovernor::default() }
    }

    /// http/2.0: one connection, all requests multiplexed.
    pub fn http2() -> Self {
        SimpleTool { name: "http2", pp_level: HTTP2_MULTIPLEX_DEPTH, handshake_rtts: 0.0, governor: OndemandGovernor::default() }
    }
}

impl Algorithm for SimpleTool {
    fn name(&self) -> &'static str {
        self.name
    }

    fn timeout(&self) -> SimDuration {
        // No tuning happens; the timeout only paces telemetry draining.
        SimDuration::from_secs(5.0)
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        // One partition holding the whole dataset in order, one channel,
        // one stream; no chunking (these tools are file-at-a-time).
        let total: Bytes = dataset.files.iter().map(|f| f.size).sum();
        let n = dataset.files.len().max(1);
        let partition = Partition {
            name: "all",
            files: dataset.files.clone(),
            pp_level: self.pp_level,
            parallelism: 1,
            chunk_size: total / n as f64,
        };
        InitPlan {
            partitions: vec![partition],
            num_channels: 1,
            client_cpu: CpuState::performance(testbed.client_cpu.clone()),
            handshake_rtts: self.handshake_rtts,
        }
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // No runtime tuning — only the OS frequency governor acts.
        self.governor.control(telemetry, ctx.client);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    fn outcome(kind: AlgorithmKind, dataset: &str) -> crate::sim::session::SessionOutcome {
        let mut cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::by_name(dataset, 4).unwrap(),
            kind,
        );
        cfg.max_sim_time = SimDuration::from_secs(100_000.0);
        run_session(&cfg)
    }

    #[test]
    fn single_connection_only() {
        let mut t = SimpleTool::http2();
        let plan = t.init(&testbeds::cloudlab(), &standard::medium_dataset(1));
        assert_eq!(plan.num_channels, 1);
        assert_eq!(plan.partitions.len(), 1);
        assert!(plan.client_cpu.at_max_cores() && plan.client_cpu.at_max_freq());
    }

    #[test]
    fn http2_beats_curl_on_small_files() {
        let h2 = outcome(AlgorithmKind::Http2, "small");
        let curl = outcome(AlgorithmKind::Curl, "small");
        assert!(h2.completed && curl.completed);
        assert!(
            h2.avg_throughput.as_mbps() > 3.0 * curl.avg_throughput.as_mbps(),
            "http2 {} vs curl {}",
            h2.avg_throughput,
            curl.avg_throughput
        );
    }

    #[test]
    fn curl_beats_wget() {
        let curl = outcome(AlgorithmKind::Curl, "small");
        let wget = outcome(AlgorithmKind::Wget, "small");
        assert!(
            curl.avg_throughput.as_mbps() > 1.5 * wget.avg_throughput.as_mbps(),
            "curl {} vs wget {}",
            curl.avg_throughput,
            wget.avg_throughput
        );
    }

    #[test]
    fn http2_window_limited_on_wan() {
        // One multiplexed connection cannot exceed avg_win / RTT.
        let h2 = outcome(AlgorithmKind::Http2, "large");
        let cap = testbeds::cloudlab().link.channel_throughput();
        assert!(
            h2.avg_throughput.as_bits_per_sec() <= 1.05 * cap.as_bits_per_sec(),
            "http2 {} vs single-stream cap {}",
            h2.avg_throughput,
            cap
        );
    }
}
