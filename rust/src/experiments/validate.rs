//! Tables I and II — regenerate the testbed and dataset characteristics
//! tables and check them against the paper's numbers.

use crate::config::testbeds;
use crate::dataset::standard;
use crate::metrics::Table;

/// Table I: testbed characteristics.
pub fn table1() -> Table {
    let mut t = Table::new(
        "Table I — testbed characteristics",
        &["testbed", "bandwidth", "RTT", "BDP", "server CPU", "client CPU"],
    );
    for tb in testbeds::all() {
        t.push_row(vec![
            tb.name.to_string(),
            format!("{}", tb.link.capacity),
            format!("{:.0} ms", tb.link.rtt.as_millis()),
            format!("{:.1} MB", tb.bdp().as_mb()),
            tb.server_cpu.name.clone(),
            tb.client_cpu.name.clone(),
        ]);
    }
    t
}

/// Table II: dataset characteristics (regenerated from the generators).
pub fn table2(seed: u64) -> Table {
    let mut t = Table::new(
        "Table II — dataset characteristics",
        &["dataset", "num files", "total size", "avg file size", "std dev"],
    );
    for name in standard::STANDARD_NAMES {
        let d = standard::by_name(name, seed).unwrap();
        t.push_row(vec![
            name.to_string(),
            d.num_files().to_string(),
            format!("{}", d.total_size()),
            format!("{}", d.avg_file_size()),
            format!("{}", d.std_file_size()),
        ]);
    }
    t
}

/// Check the regenerated values against the paper (used by `greendt
/// validate` and the figures integration test). Returns mismatch strings.
pub fn check(seed: u64) -> Vec<String> {
    let mut problems = Vec::new();
    let mut expect = |ok: bool, what: &str| {
        if !ok {
            problems.push(what.to_string());
        }
    };

    // Table I.
    let bdps = [("chameleon", 40.0), ("cloudlab", 4.5), ("didclab", 5.5)];
    for (name, mb) in bdps {
        let tb = testbeds::by_name(name).unwrap();
        expect((tb.bdp().as_mb() - mb).abs() < 0.5, &format!("{name} BDP ≈ {mb} MB"));
    }

    // Table II.
    let ds = standard::small_dataset(seed);
    expect(ds.num_files() == 20_000, "small: 20,000 files");
    expect((ds.total_size().as_gb() - 1.94).abs() < 0.15, "small: ≈1.94 GB");
    let ds = standard::medium_dataset(seed);
    expect(ds.num_files() == 5_000, "medium: 5,000 files");
    expect((ds.total_size().as_gb() - 11.70).abs() < 0.5, "medium: ≈11.70 GB");
    let ds = standard::large_dataset(seed);
    expect(ds.num_files() == 128, "large: 128 files");
    expect((ds.total_size().as_gb() - 27.85).abs() < 1.0, "large: ≈27.85 GB");

    problems
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render() {
        let t1 = table1();
        assert_eq!(t1.rows.len(), 3);
        let t2 = table2(42);
        assert_eq!(t2.rows.len(), 4);
        assert!(t2.to_markdown().contains("mixed"));
    }

    #[test]
    fn paper_values_check_out() {
        let problems = check(42);
        assert!(problems.is_empty(), "mismatches: {problems:?}");
    }
}
