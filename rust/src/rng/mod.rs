//! Deterministic pseudo-random number generation.
//!
//! The image's crate set has no `rand`, so GreenDT carries its own small,
//! fully deterministic PRNG: **xoshiro256\*\*** (Blackman & Vigna), plus the
//! distributions the simulator needs (uniform, normal, lognormal,
//! exponential). Determinism matters here: every experiment in
//! EXPERIMENTS.md is reproducible from its seed.

mod xoshiro;
mod distributions;

pub use distributions::{Distribution, Exponential, LogNormal, Normal, Uniform};
pub use xoshiro::Xoshiro256;

/// Convenience: derive a child RNG from a parent seed and a stream label so
/// independent subsystems (dataset generation, background traffic, loss
/// events) never share a stream.
pub fn stream(seed: u64, label: &str) -> Xoshiro256 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325; // FNV-1a 64
    for b in label.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    Xoshiro256::seeded(seed ^ h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn streams_are_independent() {
        let a: Vec<u64> = (0..4).map(|_| 0).scan(stream(7, "a"), |r, _| Some(r.next_u64())).collect();
        let b: Vec<u64> = (0..4).map(|_| 0).scan(stream(7, "b"), |r, _| Some(r.next_u64())).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn streams_are_deterministic() {
        let mut r1 = stream(7, "net");
        let mut r2 = stream(7, "net");
        for _ in 0..16 {
            assert_eq!(r1.next_u64(), r2.next_u64());
        }
    }
}
