//! Alan et al. [2,3] — the Figure 4 comparators.
//!
//! "Alan et al. investigated the energy consumption and throughput of
//! data transfer under different concurrency and parallelism levels. They
//! proposed a heuristic based parameter search to improve performance and
//! energy consumption" (§VI). Their search runs *before* the transfer
//! (probing a few candidate settings against the path model built from
//! history) and the winner is applied statically — no runtime adaptation,
//! no weight redistribution, and no CPU scaling.
//!
//! Compared with Ismail et al.: the offline search finds a reasonable
//! channel count (it is not hard-coded), but it still carries the
//! buffer≈BDP ⇒ parallelism=1 lineage and cannot react to background
//! traffic or to partitions draining at different speeds.

use crate::config::Testbed;
use crate::coordinator::algorithm::{Algorithm, InitPlan};
use crate::coordinator::load_control::{Governor, OndemandGovernor};
use crate::cpusim::CpuState;
use crate::dataset::{partition_files, Dataset};
use crate::sim::{Telemetry, TuneCtx};
use crate::units::SimDuration;

/// Candidate concurrency levels their offline search probes.
const SEARCH_CANDIDATES: [u32; 5] = [1, 2, 4, 8, 16];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Goal {
    MinEnergy,
    MaxThroughput,
}

/// Alan et al. static heuristic-search tuner.
#[derive(Debug)]
pub struct Alan {
    goal: Goal,
    chosen: u32,
    governor: OndemandGovernor,
}

impl Alan {
    /// Alan et al. tuned for minimum energy.
    pub fn min_energy() -> Self {
        Alan { goal: Goal::MinEnergy, chosen: 1, governor: OndemandGovernor::default() }
    }

    /// Alan et al. tuned for maximum throughput.
    pub fn max_throughput() -> Self {
        Alan { goal: Goal::MaxThroughput, chosen: 1, governor: OndemandGovernor::default() }
    }

    /// The offline search: score each candidate channel count against the
    /// *historical* path model — their history was collected with
    /// BDP-sized buffers on quiet paths, so it believes ~8 channels
    /// saturate any route (the staleness the paper exploits: the live
    /// path's per-stream throughput is far lower).
    fn search(&self, testbed: &Testbed) -> u32 {
        let capacity = testbed.link.capacity.as_bits_per_sec();
        let per_channel = capacity / 8.0;
        let mut best = SEARCH_CANDIDATES[0];
        let mut best_score = f64::NEG_INFINITY;
        for &c in &SEARCH_CANDIDATES {
            let tput = (c as f64 * per_channel).min(capacity);
            let score = match self.goal {
                Goal::MaxThroughput => tput,
                // Energy model of their heuristic: transfer time dominates,
                // but every extra channel costs CPU power; the knee of
                // time-vs-channels is where they stop.
                Goal::MinEnergy => tput - 0.08 * capacity * c as f64 / 2.0,
            };
            if score > best_score + 1e-9 {
                best_score = score;
                best = c;
            }
        }
        best
    }
}

impl Algorithm for Alan {
    fn name(&self) -> &'static str {
        match self.goal {
            Goal::MinEnergy => "Alan-ME",
            Goal::MaxThroughput => "Alan-MT",
        }
    }

    fn timeout(&self) -> SimDuration {
        SimDuration::from_secs(5.0)
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        let mut partitions = partition_files(dataset, testbed.bdp());
        for p in &mut partitions {
            p.parallelism = 1; // buffer ≈ BDP lineage (see module docs)
        }
        self.chosen = self.search(testbed);
        InitPlan::new(
            partitions,
            self.chosen,
            CpuState::performance(testbed.client_cpu.clone()),
        )
    }

    fn on_timeout(&mut self, telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // Static after the offline search; only the OS governor acts.
        self.governor.control(telemetry, ctx.client);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    #[test]
    fn search_picks_saturating_count_for_throughput() {
        let mut a = Alan::max_throughput();
        a.init(&testbeds::cloudlab(), &standard::medium_dataset(1));
        // CloudLab knee ≈ 4.5 channels: the search should pick 8 (first
        // candidate above the knee).
        assert!(a.chosen >= 4 && a.chosen <= 8, "chose {}", a.chosen);
    }

    #[test]
    fn energy_goal_picks_fewer_channels() {
        let tb = testbeds::chameleon();
        let ds = standard::medium_dataset(1);
        let mut me = Alan::min_energy();
        let mut mt = Alan::max_throughput();
        me.init(&tb, &ds);
        mt.init(&tb, &ds);
        assert!(me.chosen <= mt.chosen, "ME {} vs MT {}", me.chosen, mt.chosen);
    }

    #[test]
    fn runs_performance_governor() {
        let mut a = Alan::min_energy();
        let plan = a.init(&testbeds::didclab(), &standard::small_dataset(1));
        assert!(plan.client_cpu.at_max_cores() && plan.client_cpu.at_max_freq());
    }

    #[test]
    fn our_me_uses_less_energy_than_alan_me() {
        let ds = standard::large_dataset(4);
        let ours = run_session(&SessionConfig::new(
            testbeds::chameleon(),
            ds.clone(),
            AlgorithmKind::MinEnergy,
        ));
        let theirs = run_session(&SessionConfig::new(
            testbeds::chameleon(),
            ds,
            AlgorithmKind::AlanMinEnergy,
        ));
        assert!(ours.completed && theirs.completed);
        assert!(
            ours.client_energy.as_joules() < theirs.client_energy.as_joules(),
            "ME {} vs Alan-ME {}",
            ours.client_energy,
            theirs.client_energy
        );
    }
}
