//! Data volume newtype.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub, SubAssign};

/// A data volume in bytes.
///
/// Backed by `f64`: the simulator moves fractional bytes per tick and the
/// largest dataset (27.85 GB, Table II) is far below the 2^53 exact-integer
/// limit.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Bytes(f64);

impl Bytes {
    /// Zero bytes.
    pub const ZERO: Bytes = Bytes(0.0);

    /// Construct from a raw byte count. Negative inputs clamp to zero.
    pub fn new(bytes: f64) -> Self {
        Bytes(if bytes > 0.0 { bytes } else { 0.0 })
    }

    /// Construct from kilobytes (10³ bytes).
    pub fn from_kb(kb: f64) -> Self {
        Bytes::new(kb * 1e3)
    }

    /// Construct from megabytes (10⁶ bytes).
    pub fn from_mb(mb: f64) -> Self {
        Bytes::new(mb * 1e6)
    }

    /// Construct from gigabytes (10⁹ bytes).
    pub fn from_gb(gb: f64) -> Self {
        Bytes::new(gb * 1e9)
    }

    /// The raw byte count.
    pub fn as_f64(self) -> f64 {
        self.0
    }

    /// Value in kilobytes.
    pub fn as_kb(self) -> f64 {
        self.0 / 1e3
    }

    /// Value in megabytes.
    pub fn as_mb(self) -> f64 {
        self.0 / 1e6
    }

    /// Value in gigabytes.
    pub fn as_gb(self) -> f64 {
        self.0 / 1e9
    }

    /// True when no bytes remain.
    pub fn is_zero(self) -> bool {
        self.0 <= 0.0
    }

    /// The smaller of two volumes.
    pub fn min(self, other: Bytes) -> Bytes {
        Bytes(self.0.min(other.0))
    }

    /// The larger of two volumes.
    pub fn max(self, other: Bytes) -> Bytes {
        Bytes(self.0.max(other.0))
    }

    /// Saturating subtraction (never negative).
    pub fn saturating_sub(self, other: Bytes) -> Bytes {
        Bytes((self.0 - other.0).max(0.0))
    }

    /// Fraction `self / total`, 0 when total is zero.
    pub fn fraction_of(self, total: Bytes) -> f64 {
        if total.0 <= 0.0 {
            0.0
        } else {
            self.0 / total.0
        }
    }
}

impl Add for Bytes {
    type Output = Bytes;
    fn add(self, rhs: Bytes) -> Bytes {
        Bytes(self.0 + rhs.0)
    }
}

impl AddAssign for Bytes {
    fn add_assign(&mut self, rhs: Bytes) {
        self.0 += rhs.0;
    }
}

impl Sub for Bytes {
    type Output = Bytes;
    fn sub(self, rhs: Bytes) -> Bytes {
        Bytes::new(self.0 - rhs.0)
    }
}

impl SubAssign for Bytes {
    fn sub_assign(&mut self, rhs: Bytes) {
        *self = *self - rhs;
    }
}

impl Mul<f64> for Bytes {
    type Output = Bytes;
    fn mul(self, rhs: f64) -> Bytes {
        Bytes::new(self.0 * rhs)
    }
}

impl Div<f64> for Bytes {
    type Output = Bytes;
    fn div(self, rhs: f64) -> Bytes {
        Bytes::new(self.0 / rhs)
    }
}

impl Div for Bytes {
    /// Ratio of two volumes (dimensionless).
    type Output = f64;
    fn div(self, rhs: Bytes) -> f64 {
        if rhs.0 == 0.0 {
            0.0
        } else {
            self.0 / rhs.0
        }
    }
}

impl Sum for Bytes {
    fn sum<I: Iterator<Item = Bytes>>(iter: I) -> Bytes {
        iter.fold(Bytes::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for Bytes {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1e9 {
            write!(f, "{:.2} GB", self.as_gb())
        } else if self.0 >= 1e6 {
            write!(f, "{:.2} MB", self.as_mb())
        } else if self.0 >= 1e3 {
            write!(f, "{:.2} KB", self.as_kb())
        } else {
            write!(f, "{:.0} B", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conversions_round_trip() {
        assert_eq!(Bytes::from_mb(2.5).as_kb(), 2500.0);
        assert_eq!(Bytes::from_gb(1.0).as_mb(), 1000.0);
    }

    #[test]
    fn negative_clamps_to_zero() {
        assert_eq!(Bytes::new(-5.0), Bytes::ZERO);
        assert_eq!(Bytes::new(3.0) - Bytes::new(10.0), Bytes::ZERO);
    }

    #[test]
    fn saturating_sub_never_negative() {
        let a = Bytes::new(1.0);
        let b = Bytes::new(2.0);
        assert_eq!(a.saturating_sub(b), Bytes::ZERO);
        assert_eq!(b.saturating_sub(a), Bytes::new(1.0));
    }

    #[test]
    fn fraction_of_zero_total_is_zero() {
        assert_eq!(Bytes::new(5.0).fraction_of(Bytes::ZERO), 0.0);
        assert_eq!(Bytes::new(5.0).fraction_of(Bytes::new(10.0)), 0.5);
    }

    #[test]
    fn display_picks_unit() {
        assert_eq!(format!("{}", Bytes::from_gb(2.0)), "2.00 GB");
        assert_eq!(format!("{}", Bytes::new(512.0)), "512 B");
    }

    #[test]
    fn sum_over_iter() {
        let total: Bytes = (0..4).map(|i| Bytes::new(i as f64)).sum();
        assert_eq!(total, Bytes::new(6.0));
    }
}
