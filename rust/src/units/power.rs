//! Power and energy newtypes.

use super::SimDuration;
use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// Instantaneous power draw in watts.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Power(f64);

impl Power {
    /// Zero watts.
    pub const ZERO: Power = Power(0.0);

    /// Construct from watts.
    pub fn from_watts(w: f64) -> Self {
        Power(if w > 0.0 { w } else { 0.0 })
    }

    /// Value in watts.
    pub fn as_watts(self) -> f64 {
        self.0
    }

    /// Energy accumulated over an interval at this constant power.
    pub fn over(self, dt: SimDuration) -> Energy {
        Energy::from_joules(self.0 * dt.as_secs())
    }

    /// The smaller of two power draws.
    pub fn min(self, other: Power) -> Power {
        Power(self.0.min(other.0))
    }

    /// The larger of two power draws.
    pub fn max(self, other: Power) -> Power {
        Power(self.0.max(other.0))
    }
}

impl Add for Power {
    type Output = Power;
    fn add(self, rhs: Power) -> Power {
        Power(self.0 + rhs.0)
    }
}

impl AddAssign for Power {
    fn add_assign(&mut self, rhs: Power) {
        self.0 += rhs.0;
    }
}

impl Sub for Power {
    type Output = Power;
    fn sub(self, rhs: Power) -> Power {
        Power::from_watts(self.0 - rhs.0)
    }
}

impl Mul<f64> for Power {
    type Output = Power;
    fn mul(self, rhs: f64) -> Power {
        Power::from_watts(self.0 * rhs)
    }
}

impl Sum for Power {
    fn sum<I: Iterator<Item = Power>>(iter: I) -> Power {
        iter.fold(Power::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for Power {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.1} W", self.0)
    }
}

/// Accumulated energy in joules.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct Energy(f64);

impl Energy {
    /// Zero joules.
    pub const ZERO: Energy = Energy(0.0);

    /// Construct from joules.
    pub fn from_joules(j: f64) -> Self {
        Energy(if j > 0.0 { j } else { 0.0 })
    }

    /// Construct from kilojoules.
    pub fn from_kilojoules(kj: f64) -> Self {
        Energy::from_joules(kj * 1e3)
    }

    /// Value in joules.
    pub fn as_joules(self) -> f64 {
        self.0
    }

    /// Value in kilojoules.
    pub fn as_kilojoules(self) -> f64 {
        self.0 / 1e3
    }

    /// Value in watt-hours.
    pub fn as_watt_hours(self) -> f64 {
        self.0 / 3600.0
    }

    /// Average power if this energy was spent over `dt`.
    pub fn average_power(self, dt: SimDuration) -> Power {
        if dt.as_secs() <= 0.0 {
            Power::ZERO
        } else {
            Power::from_watts(self.0 / dt.as_secs())
        }
    }

    /// True when no energy has accrued.
    pub fn is_zero(self) -> bool {
        self.0 <= 0.0
    }

    /// Saturating subtraction.
    pub fn saturating_sub(self, other: Energy) -> Energy {
        Energy::from_joules(self.0 - other.0)
    }
}

impl Add for Energy {
    type Output = Energy;
    fn add(self, rhs: Energy) -> Energy {
        Energy(self.0 + rhs.0)
    }
}

impl AddAssign for Energy {
    fn add_assign(&mut self, rhs: Energy) {
        self.0 += rhs.0;
    }
}

impl Sub for Energy {
    type Output = Energy;
    fn sub(self, rhs: Energy) -> Energy {
        Energy::from_joules(self.0 - rhs.0)
    }
}

impl Mul<f64> for Energy {
    type Output = Energy;
    fn mul(self, rhs: f64) -> Energy {
        Energy::from_joules(self.0 * rhs)
    }
}

impl Div for Energy {
    type Output = f64;
    fn div(self, rhs: Energy) -> f64 {
        if rhs.0 == 0.0 {
            0.0
        } else {
            self.0 / rhs.0
        }
    }
}

impl Sum for Energy {
    fn sum<I: Iterator<Item = Energy>>(iter: I) -> Energy {
        iter.fold(Energy::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for Energy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1e3 {
            write!(f, "{:.2} kJ", self.as_kilojoules())
        } else {
            write!(f, "{:.1} J", self.0)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn power_over_time_is_energy() {
        let e = Power::from_watts(50.0).over(SimDuration::from_secs(10.0));
        assert_eq!(e.as_joules(), 500.0);
    }

    #[test]
    fn average_power_round_trip() {
        let e = Energy::from_joules(500.0);
        let p = e.average_power(SimDuration::from_secs(10.0));
        assert_eq!(p.as_watts(), 50.0);
        assert_eq!(Energy::from_joules(1.0).average_power(SimDuration::ZERO), Power::ZERO);
    }

    #[test]
    fn watt_hours() {
        assert_eq!(Energy::from_joules(3600.0).as_watt_hours(), 1.0);
    }

    #[test]
    fn energy_ratio() {
        let a = Energy::from_joules(52.0);
        let b = Energy::from_joules(100.0);
        assert!((a / b - 0.52).abs() < 1e-12);
    }
}
