//! The whole-world simulation stepper.

use super::{Telemetry, TickStats};
use crate::config::Testbed;
use crate::cpusim::{CpuDemand, CpuState};
use crate::netsim::Link;
use crate::power::{standard_power, NodeMeter, PowerModel, RaplMeter};
use crate::rng::{self, Xoshiro256};
use crate::transfer::TransferEngine;
use crate::units::{Bytes, Energy, Rate, SimDuration, SimTime};

/// Fraction of CPU capacity the transfer application can actually use
/// (kernel, interrupts and the tuner itself take the rest). Re-exported
/// as `crate::sim::MAX_APP_UTILIZATION`.
pub const MAX_APP_UTILIZATION: f64 = 0.92;

/// The complete simulated world for one transfer session.
#[derive(Debug, Clone)]
pub struct Simulation {
    pub link: Link,
    pub engine: TransferEngine,
    /// Client CPU setting — the one the tuning algorithms actuate.
    pub client: CpuState,
    /// Server CPU setting — pinned to the performance governor (the paper:
    /// "there is no frequency scaling on the server").
    pub server: CpuState,
    client_power: PowerModel,
    server_power: PowerModel,
    /// RAPL package meter on the client.
    pub client_rapl: RaplMeter,
    /// Wall meter on the client (package + platform base).
    pub client_node: NodeMeter,
    /// RAPL package meter on the server.
    pub server_rapl: RaplMeter,
    /// Whether this testbed reports client energy from the wall meter.
    wall_meter: bool,
    pub now: SimTime,
    tick: SimDuration,
    rng: Xoshiro256,
    /// GreenDT extension (the paper leaves the server unscaled): when
    /// enabled, an Algorithm-3 threshold policy also drives the server's
    /// cores/frequency at every telemetry drain.
    pub server_autoscale: bool,
    // Interval accumulators (reset by `drain_telemetry`).
    acc_moved: Bytes,
    acc_time: SimDuration,
    acc_load: f64,
    acc_server_load: f64,
    acc_load_ticks: u32,
    acc_client_energy_start: Energy,
    // Last-tick cached values used for CPU overhead estimation.
    last_requests_per_sec: f64,
    last_stats: TickStats,
}

impl Simulation {
    /// Assemble a session world. `client` is the initial CPU setting
    /// chosen by the algorithm (Alg. 1 lines 14–20).
    pub fn new(
        testbed: &Testbed,
        engine: TransferEngine,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
    ) -> Self {
        Self::with_bandwidth_events(testbed, engine, client, tick, seed, Vec::new())
    }

    /// Like [`Self::new`] with scripted background-traffic events
    /// (failure injection).
    pub fn with_bandwidth_events(
        testbed: &Testbed,
        engine: TransferEngine,
        client: CpuState,
        tick: SimDuration,
        seed: u64,
        events: Vec<crate::netsim::BandwidthEvent>,
    ) -> Self {
        let link = testbed.make_link_with_events(events);
        let client_power = standard_power(&testbed.client_cpu);
        let server_power = standard_power(&testbed.server_cpu);
        Simulation {
            link,
            engine,
            client,
            server: CpuState::performance(testbed.server_cpu.clone()),
            client_power,
            server_power,
            client_rapl: RaplMeter::new(),
            client_node: NodeMeter::new(testbed.client_base_power),
            server_rapl: RaplMeter::new(),
            wall_meter: testbed.wall_meter,
            now: SimTime::ZERO,
            tick,
            rng: rng::stream(seed, "sim"),
            server_autoscale: false,
            acc_moved: Bytes::ZERO,
            acc_time: SimDuration::ZERO,
            acc_load: 0.0,
            acc_server_load: 0.0,
            acc_load_ticks: 0,
            acc_client_energy_start: Energy::ZERO,
            last_requests_per_sec: 0.0,
            last_stats: TickStats::default(),
        }
    }

    pub fn tick_len(&self) -> SimDuration {
        self.tick
    }

    pub fn is_done(&self) -> bool {
        self.engine.is_done()
    }

    /// Client energy according to the testbed's instrument (RAPL package
    /// or wall meter).
    pub fn client_energy(&self) -> Energy {
        if self.wall_meter {
            self.client_node.total()
        } else {
            self.client_rapl.total()
        }
    }

    pub fn server_energy(&self) -> Energy {
        self.server_rapl.total()
    }

    pub fn last_stats(&self) -> TickStats {
        self.last_stats
    }

    /// Advance the world by one tick.
    pub fn step(&mut self) -> TickStats {
        let dt = self.tick;
        self.link.tick(self.now, dt, &mut self.rng);

        // End-system achievable throughput at current settings, using the
        // previous tick's request rate and the current stream count as the
        // overhead estimate (one-step fixed point; error is O(tick)).
        let streams = self.engine.open_streams() as f64;
        let client_cap = self.client.spec().achievable_bytes_per_sec(
            self.client.active_cores(),
            self.client.freq(),
            self.last_requests_per_sec,
            streams,
            MAX_APP_UTILIZATION,
        );
        let server_cap = self.server.spec().achievable_bytes_per_sec(
            self.server.active_cores(),
            self.server.freq(),
            self.last_requests_per_sec,
            streams,
            MAX_APP_UTILIZATION,
        );
        let cap = client_cap.min(server_cap);

        let out = self.engine.tick(&self.link, dt, cap);
        self.last_requests_per_sec = out.requests_per_sec;

        // CPU loads implied by the achieved goodput.
        let demand = CpuDemand {
            bytes_per_sec: out.goodput.as_bytes_per_sec(),
            requests_per_sec: out.requests_per_sec,
            open_streams: out.open_streams as f64,
        };
        let client_load =
            self.client.spec().load(&demand, self.client.active_cores(), self.client.freq());
        let server_load =
            self.server.spec().load(&demand, self.server.active_cores(), self.server.freq());

        // Power draw at the operating point.
        let client_power = self.client_power.package_power(
            self.client.active_cores(),
            self.client.freq(),
            client_load,
            out.goodput.as_bytes_per_sec(),
        );
        let server_power = self.server_power.package_power(
            self.server.active_cores(),
            self.server.freq(),
            server_load,
            out.goodput.as_bytes_per_sec(),
        );
        self.client_rapl.record(self.now, client_power, dt);
        self.client_node.record(self.now, client_power, dt);
        self.server_rapl.record(self.now, server_power, dt);

        self.now += dt;
        self.acc_moved += out.moved;
        self.acc_time += dt;
        self.acc_load += client_load.min(4.0);
        self.acc_server_load += server_load.min(4.0);
        self.acc_load_ticks += 1;

        let stats = TickStats {
            goodput: out.goodput,
            moved: out.moved,
            client_load,
            server_load,
            client_power,
            server_power,
            open_streams: out.open_streams,
        };
        self.last_stats = stats;
        stats
    }

    /// Path + transfer model view for the predictive governor.
    fn net_view(&self) -> crate::sim::telemetry::NetView {
        let p = &self.link.params;
        let parts = self.engine.partitions();
        let remaining: f64 = parts.iter().map(|x| x.remaining.as_f64()).sum();
        let (mut avg_file, mut pp) = (0.0, 0.0);
        if remaining > 0.0 {
            for x in parts {
                let w = x.remaining.as_f64() / remaining;
                avg_file += w * x.avg_file_size.as_f64();
                pp += w * x.pp_level as f64;
            }
        }
        let channels = self.engine.num_channels().max(1) as f64;
        crate::sim::telemetry::NetView {
            available_bps: self.link.available().as_bytes_per_sec(),
            rtt_s: p.rtt.as_secs(),
            avg_win_bytes: p.avg_win.as_f64(),
            knee_streams: p.knee_streams(),
            overload_gamma: p.overload_gamma,
            overload_floor: p.overload_floor,
            parallelism: (self.engine.open_streams() as f64 / channels).max(1.0),
            avg_file_bytes: avg_file.max(1.0),
            pp_level: pp.max(1.0),
        }
    }

    /// Read and reset the interval accumulators — called by the session
    /// driver at each tuning timeout to build the algorithm's view.
    pub fn drain_telemetry(&mut self) -> Telemetry {
        let interval_energy = self.client_energy().saturating_sub(self.acc_client_energy_start);
        let tel = Telemetry {
            now: self.now,
            avg_throughput: Rate::average(self.acc_moved, self.acc_time),
            interval_energy,
            avg_power: interval_energy.average_power(self.acc_time),
            cpu_load: if self.acc_load_ticks == 0 {
                0.0
            } else {
                self.acc_load / self.acc_load_ticks as f64
            },
            remaining: self.engine.remaining(),
            total: self.engine.total(),
            elapsed: self.now.since(SimTime::ZERO),
            num_channels: self.engine.num_channels(),
            open_streams: self.engine.open_streams(),
            net: self.net_view(),
        };
        // Server-side scaling extension: Algorithm 3 on the server,
        // driven by the same interval cadence.
        if self.server_autoscale && self.acc_load_ticks > 0 {
            let load = self.acc_server_load / self.acc_load_ticks as f64;
            let th = crate::coordinator::load_control::LoadThresholds::default();
            if load > th.max_load {
                if !self.server.increase_cores() {
                    self.server.increase_freq();
                }
            } else if load < th.min_load {
                if !self.server.decrease_freq() {
                    self.server.decrease_cores();
                }
            }
        }
        self.acc_moved = Bytes::ZERO;
        self.acc_time = SimDuration::ZERO;
        self.acc_load = 0.0;
        self.acc_server_load = 0.0;
        self.acc_load_ticks = 0;
        self.acc_client_energy_start = self.client_energy();
        tel
    }

    /// Average power of the client at an arbitrary hypothetical setting —
    /// exposed for the predictive governor's candidate evaluation.
    pub fn client_power_model(&self) -> &PowerModel {
        &self.client_power
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::{partition_files, standard};

    fn make_sim(testbed: &str, dataset: &str, channels: u32) -> Simulation {
        let tb = testbeds::by_name(testbed).unwrap();
        let ds = standard::by_name(dataset, 5).unwrap();
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(channels);
        let client = CpuState::performance(tb.client_cpu.clone());
        Simulation::new(&tb, engine, client, SimDuration::from_millis(100.0), 11)
    }

    #[test]
    fn stepping_moves_data_and_burns_energy() {
        let mut sim = make_sim("cloudlab", "medium", 6);
        for _ in 0..100 {
            sim.step();
        }
        assert!(sim.engine.remaining() < sim.engine.total());
        assert!(sim.client_energy().as_joules() > 0.0);
        assert!(sim.server_energy().as_joules() > 0.0);
        assert!((sim.now.as_secs() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn telemetry_reflects_interval() {
        let mut sim = make_sim("cloudlab", "medium", 6);
        for _ in 0..50 {
            sim.step();
        }
        let tel = sim.drain_telemetry();
        assert!(tel.avg_throughput.as_mbps() > 50.0, "tput {}", tel.avg_throughput);
        assert!(tel.interval_energy.as_joules() > 0.0);
        assert!(tel.cpu_load > 0.0);
        assert!((tel.elapsed.as_secs() - 5.0).abs() < 1e-9);
        // Drained: second read covers an empty interval.
        let tel2 = sim.drain_telemetry();
        assert_eq!(tel2.avg_throughput, Rate::ZERO);
    }

    #[test]
    fn min_freq_single_core_caps_10gbps() {
        let tb = testbeds::chameleon();
        let ds = standard::large_dataset(5);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(8);
        let client = CpuState::min_energy_start(tb.client_cpu.clone());
        let mut sim = Simulation::new(&tb, engine, client, SimDuration::from_millis(100.0), 3);
        for _ in 0..100 {
            sim.step();
        }
        let tel = sim.drain_telemetry();
        // 1 core @ 1.2 GHz can push at most ~0.46 GB/s ≈ 3.7 Gbps.
        assert!(
            tel.avg_throughput.as_gbps() < 4.5,
            "CPU should bottleneck: {}",
            tel.avg_throughput
        );
        assert!(tel.cpu_load > 0.85, "load {}", tel.cpu_load);
    }

    #[test]
    fn performance_governor_uses_more_power_when_idle_ish() {
        let mut perf = make_sim("cloudlab", "large", 4);
        let tb = testbeds::cloudlab();
        let ds = standard::large_dataset(5);
        let parts = partition_files(&ds, tb.bdp());
        let mut engine = TransferEngine::new(&parts, tb.link.avg_win);
        engine.set_num_channels(4);
        let low = CpuState::min_energy_start(tb.client_cpu.clone());
        let mut eco = Simulation::new(&tb, engine, low, SimDuration::from_millis(100.0), 11);
        for _ in 0..100 {
            perf.step();
            eco.step();
        }
        let e_perf = perf.client_rapl.total();
        let e_eco = eco.client_rapl.total();
        assert!(
            e_perf.as_joules() > 1.5 * e_eco.as_joules(),
            "perf {} vs eco {}",
            e_perf,
            e_eco
        );
    }

    #[test]
    fn wall_meter_selected_on_didclab() {
        let mut sim = make_sim("didclab", "medium", 4);
        for _ in 0..10 {
            sim.step();
        }
        // Wall energy includes the platform base, so it must exceed RAPL.
        assert!(sim.client_energy() > sim.client_rapl.total());
    }
}
