//! The scale benchmark: dispatcher throughput as fleets grow, swept
//! over shard counts — shared by `cargo bench --bench bench_scale`.
//!
//! Each grid point runs an identical synchronized-arrival workload
//! (every session requested at t = 0, constant background so warm
//! epochs batch) at shard counts [`SHARD_SWEEP`], reporting
//! sim-seconds-per-wall-second per run into `BENCH_scale.json`. The
//! 1-shard run is the serial reference loop, so the committed curve
//! doubles as the speedup claim for the sharded + warm-batched path:
//! `speedup_8v1` is the ratio at the largest grid point.
//!
//! Every multi-shard run is bit-compared against its point's 1-shard
//! outcome before it is reported — the bench refuses to publish a
//! throughput number for a run that broke shard-count invariance.
//!
//! The smoke grid (CI) tops out at 16 hosts / 64 sessions; the full
//! grid climbs to 1,000 hosts / 100,000 sessions.

use super::{json_f64, time_once};
use crate::coordinator::{AlgorithmKind, FleetPolicyKind, PlacementKind};
use crate::dataset::{generate, DatasetSpec};
use crate::sim::dispatcher::{
    run_dispatcher, DispatchOutcome, DispatcherConfig, HostSpec, SessionSpec,
};
use crate::units::{Bytes, SimDuration};

use super::hotpath::SessionRate;

/// Shard counts every grid point is measured at. 1 is the serial
/// reference loop; 8 is the figure the acceptance criteria track.
pub const SHARD_SWEEP: [usize; 3] = [1, 2, 8];

/// One measured run: a `(hosts, sessions)` grid point at one shard
/// count.
#[derive(Debug, Clone, Copy)]
pub struct ScalePoint {
    /// Fleet size.
    pub hosts: usize,
    /// Total sessions in the workload.
    pub sessions: usize,
    /// Shard count the run used (1 = serial reference loop).
    pub shards: usize,
    /// Measured simulated-time throughput.
    pub rate: SessionRate,
    /// Warm-batched share of all advanced ticks for this run (the
    /// stepper occupancy carve-out: legitimately shard-sensitive, so
    /// every shard count reports its own figure).
    pub warm_hit_rate: Option<f64>,
}

impl ScalePoint {
    fn to_json(self) -> String {
        format!(
            "{{\"hosts\":{},\"sessions\":{},\"shards\":{},\"sim_seconds\":{},\
             \"wall_seconds\":{},\"sim_seconds_per_wall_second\":{},\"warm_hit_rate\":{}}}",
            self.hosts,
            self.sessions,
            self.shards,
            json_f64(self.rate.sim_seconds),
            json_f64(self.rate.wall_seconds),
            json_f64(self.rate.sim_seconds_per_wall_second()),
            self.warm_hit_rate.map(json_f64).unwrap_or_else(|| "null".to_string())
        )
    }
}

/// Everything one scale sweep produced (the `BENCH_scale.json` schema).
#[derive(Debug, Clone)]
pub struct ScaleReport {
    /// True when the trimmed CI grid ran instead of the full curve.
    pub smoke: bool,
    /// Every `(hosts, sessions, shards)` run, in execution order.
    pub points: Vec<ScalePoint>,
    /// Fleet metrics of the last run (the largest grid point at 8
    /// shards) — its registry histograms (segment goodput/watts, queue
    /// wait) become the report's `histograms` section.
    pub metrics: Option<crate::obs::FleetMetrics>,
}

impl ScaleReport {
    /// 8-shard over 1-shard throughput at the largest grid point that
    /// carries both runs — the acceptance figure (≥ 4× expected: warm
    /// batching compounds with threading even on small CI runners).
    pub fn speedup_8v1(&self) -> f64 {
        let mut best = 0.0_f64;
        let mut speedup = 0.0_f64;
        for p8 in self.points.iter().filter(|p| p.shards == 8) {
            let Some(p1) = self
                .points
                .iter()
                .find(|p| p.shards == 1 && p.hosts == p8.hosts && p.sessions == p8.sessions)
            else {
                continue;
            };
            let size = (p8.hosts * p8.sessions) as f64;
            if size > best {
                best = size;
                speedup = p8.rate.sim_seconds_per_wall_second()
                    / p1.rate.sim_seconds_per_wall_second().max(1e-12);
            }
        }
        speedup
    }

    /// The machine-readable report (the `BENCH_scale.json` schema).
    pub fn to_json(&self) -> String {
        let grid: Vec<String> = self.points.iter().map(|p| p.to_json()).collect();
        let hists = self
            .metrics
            .as_ref()
            .map(|m| m.registry.histograms_json())
            .unwrap_or_else(|| "{}".to_string());
        format!(
            "{{\n  \"bench\": \"scale\",\n  \"measured\": true,\n  \"smoke\": {},\n  \
             \"shard_sweep\": [1, 2, 8],\n  \"speedup_8v1\": {},\n  \"grid\": [\n    {}\n  ],\n  \
             \"histograms\": {}\n}}\n",
            self.smoke,
            json_f64(self.speedup_8v1()),
            grid.join(",\n    "),
            hists
        )
    }

    /// Write [`Self::to_json`] to `path`.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// One grid point's workload: `sessions` synchronized arrivals (all
/// requested at t = 0) over `hosts` machines cycling the three paper
/// testbeds, round-robin placement (an O(hosts) decision, so placement
/// cost cannot drown the stepping cost being measured), constant
/// background so warm epochs batch. Arrivals beyond the slot pools
/// queue and re-admit as sessions finish — admission control is part of
/// the measured path on purpose.
fn scale_cfg(hosts: usize, sessions: usize, shards: usize, smoke: bool) -> DispatcherConfig {
    let testbeds = crate::config::testbeds::all();
    let host_specs: Vec<HostSpec> = (0..hosts)
        .map(|i| {
            let tb = testbeds[i % testbeds.len()].clone();
            HostSpec::new(format!("host{i}-{}", tb.name), tb).with_max_sessions(8)
        })
        .collect();
    // Per-session micro dataset: a handful of large files so 100k
    // engines stay cheap to hold. Smoke halves the bytes again.
    let (files, avg_mb) = if smoke { (8, 32.0) } else { (16, 64.0) };
    let spec = DatasetSpec::new(
        "scale",
        files,
        Bytes::from_mb(avg_mb),
        Bytes::from_mb(avg_mb / 8.0),
    );
    let specs: Vec<SessionSpec> = (0..sessions)
        .map(|i| {
            SessionSpec::new(
                format!("session-{i}"),
                generate(&spec, 42 + i as u64),
                AlgorithmKind::MaxThroughput,
            )
        })
        .collect();
    let mut cfg = DispatcherConfig::new(host_specs, PlacementKind::RoundRobin)
        .with_sessions(specs)
        .with_seed(42)
        .with_shards(shards)
        .with_constant_bg();
    cfg.policy = FleetPolicyKind::MinEnergyFleet;
    cfg.max_sim_time = SimDuration::from_secs(28_800.0);
    // Metrics ride the measured runs: collection is segment-boundary
    // only, so the overhead is invisible next to tick stepping, and it
    // buys the warm-batch hit rate + segment histograms for the report.
    cfg.metrics = true;
    cfg
}

/// Shard-count invariance is a hard contract: refuse to report a
/// throughput for a run whose outcome drifted from the 1-shard one.
fn assert_outcomes_identical(reference: &DispatchOutcome, run: &DispatchOutcome, shards: usize) {
    assert_eq!(
        reference.fleet.duration.as_secs().to_bits(),
        run.fleet.duration.as_secs().to_bits(),
        "{shards}-shard run diverged from the serial loop on duration"
    );
    assert_eq!(
        reference.fleet.moved.as_f64().to_bits(),
        run.fleet.moved.as_f64().to_bits(),
        "{shards}-shard run diverged from the serial loop on bytes moved"
    );
    assert_eq!(
        reference.fleet.client_energy.as_joules().to_bits(),
        run.fleet.client_energy.as_joules().to_bits(),
        "{shards}-shard run diverged from the serial loop on energy"
    );
    assert_eq!(
        reference.decisions.len(),
        run.decisions.len(),
        "{shards}-shard run diverged from the serial loop on decisions"
    );
}

/// Run the sweep. `smoke` uses the trimmed CI grid; the full grid's
/// largest point is 1,000 hosts / 100,000 sessions.
pub fn run(smoke: bool) -> ScaleReport {
    let grid: &[(usize, usize)] = if smoke {
        &[(4, 16), (16, 64)]
    } else {
        &[(10, 1_000), (100, 10_000), (1_000, 100_000)]
    };
    let mut points = Vec::new();
    let mut last_metrics = None;
    for &(hosts, sessions) in grid {
        let mut serial: Option<DispatchOutcome> = None;
        for shards in SHARD_SWEEP {
            let cfg = scale_cfg(hosts, sessions, shards, smoke);
            let (out, wall) = time_once(
                &format!("dispatcher/{hosts} hosts/{sessions} sessions/{shards} shards"),
                || run_dispatcher(&cfg),
            );
            assert!(out.fleet.completed, "{hosts}x{sessions} did not finish under the time cap");
            match &serial {
                None => serial = Some(out.clone()),
                Some(reference) => assert_outcomes_identical(reference, &out, shards),
            }
            points.push(ScalePoint {
                hosts,
                sessions,
                shards,
                rate: SessionRate {
                    sim_seconds: out.fleet.duration.as_secs(),
                    wall_seconds: wall,
                },
                warm_hit_rate: out.metrics.as_ref().and_then(|m| m.warm_hit_rate()),
            });
            last_metrics = out.metrics;
        }
        println!();
    }
    let report = ScaleReport { smoke, points, metrics: last_metrics };
    println!("  speedup (8 shards vs 1, largest point): {:.2}x", report.speedup_8v1());
    if let Some(warm) = report.points.last().and_then(|p| p.warm_hit_rate) {
        println!("  warm-batch hit rate (largest point, 8 shards): {:.1}%", warm * 100.0);
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn point(hosts: usize, sessions: usize, shards: usize, rate: f64) -> ScalePoint {
        ScalePoint {
            hosts,
            sessions,
            shards,
            rate: SessionRate { sim_seconds: rate, wall_seconds: 1.0 },
            warm_hit_rate: Some(0.75),
        }
    }

    #[test]
    fn speedup_reads_the_largest_point() {
        let report = ScaleReport {
            smoke: true,
            points: vec![
                point(4, 16, 1, 100.0),
                point(4, 16, 8, 900.0), // 9x on the small point
                point(16, 64, 1, 100.0),
                point(16, 64, 8, 600.0), // 6x on the largest — this wins
            ],
            metrics: None,
        };
        assert!((report.speedup_8v1() - 6.0).abs() < 1e-9);
    }

    #[test]
    fn speedup_without_pairs_is_zero() {
        let report =
            ScaleReport { smoke: true, points: vec![point(4, 16, 2, 100.0)], metrics: None };
        assert_eq!(report.speedup_8v1(), 0.0);
    }

    #[test]
    fn report_json_shape() {
        let mut metrics = crate::obs::FleetMetrics::default();
        metrics.registry.record("goodput.segment_bps", 1e9);
        let report = ScaleReport {
            smoke: false,
            points: vec![point(4, 16, 1, 100.0), point(4, 16, 8, 500.0)],
            metrics: Some(metrics),
        };
        let j = report.to_json();
        assert!(j.contains("\"bench\": \"scale\""));
        assert!(j.contains("\"measured\": true"));
        assert!(j.contains("\"smoke\": false"));
        assert!(j.contains("\"speedup_8v1\": 5"));
        assert!(j.contains("\"hosts\":4"));
        assert!(j.contains("\"shards\":8"));
        assert!(j.contains("\"warm_hit_rate\":0.75"));
        assert!(j.contains("\"histograms\": {\"goodput.segment_bps\":{\"count\":1"), "{j}");
    }

    #[test]
    fn scale_config_builds_the_requested_fleet() {
        let cfg = scale_cfg(5, 12, 2, true);
        assert_eq!(cfg.hosts.len(), 5);
        assert_eq!(cfg.sessions.len(), 12);
        assert_eq!(cfg.shards, 2);
        assert!(cfg.constant_bg);
        // Synchronized arrivals: every session requested at t = 0.
        assert!(cfg.sessions.iter().all(|s| s.arrive_at.as_secs() == 0.0));
        // Testbeds cycle, so a 5-host fleet is heterogeneous.
        assert_ne!(cfg.hosts[0].testbed.name, cfg.hosts[1].testbed.name);
    }
}
