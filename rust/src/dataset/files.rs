//! File and dataset value types.

use crate::units::Bytes;

/// Opaque file identifier, unique within a [`Dataset`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FileId(pub u32);

/// A single file in a dataset.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FileSpec {
    /// Stable identifier within the dataset.
    pub id: FileId,
    /// File size.
    pub size: Bytes,
}

impl FileSpec {
    /// A file with the given id and size.
    pub fn new(id: u32, size: Bytes) -> Self {
        FileSpec { id: FileId(id), size }
    }
}

/// A named collection of files — the unit a transfer session moves.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Dataset family name (e.g. `"medium"`).
    pub name: String,
    /// Every file to transfer.
    pub files: Vec<FileSpec>,
}

impl Dataset {
    /// A dataset from an explicit file list.
    pub fn new(name: impl Into<String>, files: Vec<FileSpec>) -> Self {
        Dataset { name: name.into(), files }
    }

    /// Number of files.
    pub fn num_files(&self) -> usize {
        self.files.len()
    }

    /// Sum of all file sizes.
    pub fn total_size(&self) -> Bytes {
        self.files.iter().map(|f| f.size).sum()
    }

    /// Mean file size (zero for an empty dataset).
    pub fn avg_file_size(&self) -> Bytes {
        if self.files.is_empty() {
            Bytes::ZERO
        } else {
            self.total_size() / self.files.len() as f64
        }
    }

    /// Sample standard deviation of file sizes (bytes).
    pub fn std_file_size(&self) -> Bytes {
        let n = self.files.len();
        if n < 2 {
            return Bytes::ZERO;
        }
        let mean = self.avg_file_size().as_f64();
        let var = self
            .files
            .iter()
            .map(|f| {
                let d = f.size.as_f64() - mean;
                d * d
            })
            .sum::<f64>()
            / (n as f64 - 1.0);
        Bytes::new(var.sqrt())
    }

    /// Concatenate two datasets (used to build the paper's *mixed* dataset),
    /// re-assigning ids to stay unique.
    pub fn concat(name: impl Into<String>, parts: &[&Dataset]) -> Dataset {
        let mut files = Vec::new();
        let mut next_id = 0u32;
        for part in parts {
            for f in &part.files {
                files.push(FileSpec::new(next_id, f.size));
                next_id += 1;
            }
        }
        Dataset { name: name.into(), files }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds() -> Dataset {
        Dataset::new(
            "t",
            vec![
                FileSpec::new(0, Bytes::from_mb(1.0)),
                FileSpec::new(1, Bytes::from_mb(3.0)),
                FileSpec::new(2, Bytes::from_mb(2.0)),
            ],
        )
    }

    #[test]
    fn totals_and_averages() {
        let d = ds();
        assert_eq!(d.total_size(), Bytes::from_mb(6.0));
        assert_eq!(d.avg_file_size(), Bytes::from_mb(2.0));
        assert_eq!(d.num_files(), 3);
    }

    #[test]
    fn std_dev() {
        let d = ds();
        // sample std of {1,3,2} MB = 1 MB
        assert!((d.std_file_size().as_mb() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn empty_dataset_is_safe() {
        let d = Dataset::new("e", vec![]);
        assert_eq!(d.avg_file_size(), Bytes::ZERO);
        assert_eq!(d.std_file_size(), Bytes::ZERO);
        assert_eq!(d.total_size(), Bytes::ZERO);
    }

    #[test]
    fn concat_reassigns_unique_ids() {
        let a = ds();
        let b = ds();
        let m = Dataset::concat("mixed", &[&a, &b]);
        assert_eq!(m.num_files(), 6);
        let mut ids: Vec<u32> = m.files.iter().map(|f| f.id.0).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 6, "ids must be unique after concat");
        assert_eq!(m.total_size(), Bytes::from_mb(12.0));
    }
}
