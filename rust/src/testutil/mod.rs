//! Property-test-lite: a tiny deterministic property-testing framework.
//!
//! The offline crate set has no `proptest`/`quickcheck`, so GreenDT carries
//! its own minimal substitute. It supports:
//!
//! * generator combinators over [`crate::rng::Xoshiro256`],
//! * a configurable number of cases per property,
//! * first-failure reporting that prints the **seed and case index** so any
//!   failure replays deterministically,
//! * a greedy scalar shrinking pass for numeric inputs.
//!
//! ```no_run
//! use greendt::testutil::{property, Gen};
//!
//! property("addition commutes", 256, |g| {
//!     let a = g.f64_in(0.0, 1e6);
//!     let b = g.f64_in(0.0, 1e6);
//!     assert!((a + b - (b + a)).abs() < 1e-9);
//! });
//! ```

use crate::rng::Xoshiro256;

/// Per-case generator handle passed to property closures.
pub struct Gen {
    rng: Xoshiro256,
    /// Log of scalar draws made by this case, used for shrink attempts.
    draws: Vec<f64>,
    /// When replaying a shrink candidate, values to return instead of fresh
    /// random draws.
    replay: Option<Vec<f64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Gen { rng: Xoshiro256::seeded(seed), draws: Vec::new(), replay: None, cursor: 0 }
    }

    fn replaying(values: Vec<f64>) -> Self {
        Gen { rng: Xoshiro256::seeded(0), draws: Vec::new(), replay: Some(values), cursor: 0 }
    }

    fn draw(&mut self, fresh: impl FnOnce(&mut Xoshiro256) -> f64) -> f64 {
        let v = match &self.replay {
            Some(values) => {
                let v = values.get(self.cursor).copied().unwrap_or(0.0);
                self.cursor += 1;
                v
            }
            None => fresh(&mut self.rng),
        };
        self.draws.push(v);
        v
    }

    /// Uniform f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.draw(|r| r.next_f64());
        lo + (hi - lo) * v.clamp(0.0, 1.0 - f64::EPSILON)
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi >= lo);
        let span = (hi - lo + 1) as f64;
        let v = self.f64_in(0.0, span).floor() as usize;
        lo + v.min(hi - lo)
    }

    /// Uniform u32 in [lo, hi] inclusive.
    pub fn u32_in(&mut self, lo: u32, hi: u32) -> u32 {
        self.usize_in(lo as usize, hi as usize) as u32
    }

    /// A fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.f64_in(0.0, 1.0) < 0.5
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose on empty slice");
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// A vector of `n` samples from `f`.
    pub fn vec_of<T>(&mut self, n: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..n).map(|_| f(self)).collect()
    }
}

/// Outcome of running one case, capturing panics.
fn run_case<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    f: &F,
    gen: &mut Gen,
) -> Result<(), String> {
    // Use AssertUnwindSafe for the generator: it is rebuilt per case.
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(gen)));
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = if let Some(s) = e.downcast_ref::<&str>() {
                s.to_string()
            } else if let Some(s) = e.downcast_ref::<String>() {
                s.clone()
            } else {
                "<non-string panic>".to_string()
            };
            Err(msg)
        }
    }
}

/// Run `cases` random cases of `prop`. Panics (failing the enclosing
/// `#[test]`) on the first counterexample, after a greedy shrink pass.
///
/// The environment variable `GREENDT_PT_SEED` overrides the base seed for
/// replay.
pub fn property<F>(name: &str, cases: u32, prop: F)
where
    F: Fn(&mut Gen) + std::panic::RefUnwindSafe,
{
    let base_seed = std::env::var("GREENDT_PT_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(0x9e3779b97f4a7c15);

    // Silence the default panic hook while probing cases; restore after.
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));

    let mut failure: Option<(u64, Vec<f64>, String)> = None;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64).wrapping_mul(0x2545F4914F6CDD1D);
        let mut gen = Gen::new(seed);
        if let Err(msg) = run_case(&prop, &mut gen) {
            failure = Some((seed, gen.draws.clone(), msg));
            break;
        }
    }

    // Greedy shrink: try to pull each recorded scalar toward zero.
    let shrunk = failure.map(|(seed, draws, msg)| {
        let mut best = draws;
        let mut best_msg = msg;
        for _round in 0..8 {
            let mut improved = false;
            for i in 0..best.len() {
                for factor in [0.0, 0.5] {
                    let mut cand = best.clone();
                    cand[i] *= factor;
                    if cand == best {
                        continue;
                    }
                    let mut gen = Gen::replaying(cand.clone());
                    if let Err(m) = run_case(&prop, &mut gen) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            if !improved {
                break;
            }
        }
        (seed, best, best_msg)
    });

    std::panic::set_hook(prev_hook);

    if let Some((seed, draws, msg)) = shrunk {
        panic!(
            "property '{name}' failed (seed {seed}, {} draws, GREENDT_PT_SEED to replay)\n  \
             shrunk draws: {:?}\n  panic: {msg}",
            draws.len(),
            &draws[..draws.len().min(16)],
        );
    }
}

/// Assert two floats are close (absolute or relative tolerance).
pub fn assert_close(a: f64, b: f64, tol: f64, what: &str) {
    let denom = a.abs().max(b.abs()).max(1e-12);
    let rel = (a - b).abs() / denom;
    assert!(
        (a - b).abs() <= tol || rel <= tol,
        "{what}: {a} vs {b} (rel err {rel:.3e} > tol {tol:.3e})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        property("abs is non-negative", 128, |g| {
            let x = g.f64_in(-100.0, 100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        property("always fails", 16, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < -1.0, "x = {x}");
        });
    }

    #[test]
    fn usize_in_respects_bounds() {
        property("usize_in bounds", 512, |g| {
            let lo = g.usize_in(0, 10);
            let hi = lo + g.usize_in(0, 10);
            let v = g.usize_in(lo, hi);
            assert!(v >= lo && v <= hi, "{lo} <= {v} <= {hi}");
        });
    }

    #[test]
    fn choose_returns_member() {
        property("choose member", 256, |g| {
            let xs = [1, 2, 3, 5, 8];
            let c = *g.choose(&xs);
            assert!(xs.contains(&c));
        });
    }

    #[test]
    fn assert_close_accepts_close() {
        assert_close(1.0, 1.0 + 1e-12, 1e-9, "close");
    }

    #[test]
    #[should_panic]
    fn assert_close_rejects_far() {
        assert_close(1.0, 2.0, 1e-9, "far");
    }
}
