//! Fleet faults: a host degrades, dies — and the fleet carries on.
//!
//!     cargo run --release --example fleet_faults
//!
//! Part one runs the shared `benchkit::resilience` scenario twice: a
//! legacy host's link collapses mid-run and the host later dies. With
//! recovery off the stranded session crawls until the crash quarantines
//! it in the dead-letter queue; with recovery on the health monitor's
//! advisory evacuates it to the efficient host first, so the fleet
//! delivers every byte in less time for fewer joules.
//!
//! Part two scripts a crash *with* a revival on a single-host fleet:
//! the session is preempted when the host dies, waits out its
//! PenaltyBox backoff, is re-admitted once the host returns, and
//! re-sends the lost remainder — bytes are re-materialized, never
//! teleported.

use greendt::benchkit::resilience::{scenario, summarize};
use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::metrics::Table;
use greendt::resilience::{FaultSchedule, ResilienceConfig};
use greendt::sim::dispatcher::{run_dispatcher, DispatcherConfig, HostSpec, SessionSpec};
use greendt::units::SimTime;

fn main() {
    println!("== fleet_faults: scripted failures, recovery off vs on ==\n");

    let mut table = Table::new(
        "fault script: link collapse at t=40s, host death at t=800s",
        &["recovery", "delivered", "makespan", "goodput", "energy", "dead-lettered"],
    );
    for recovery in [false, true] {
        let out = run_dispatcher(&scenario(recovery));
        let s = summarize(&out);
        table.push_row(vec![
            if recovery { "on" } else { "off" }.to_string(),
            format!("{:.2} GB", s.delivered_bytes / 1e9),
            format!("{:.0} s", s.duration_s),
            format!("{:.1} MB/s", s.goodput_bps / 1e6),
            format!("{:.0} J", s.joules),
            s.dead_lettered.to_string(),
        ]);
        for f in &out.faults {
            println!(
                "recovery {}: t={:.0}s  {} on {} ({} sessions hit)",
                if recovery { "on " } else { "off" },
                f.t_secs,
                f.kind.id(),
                f.host_name,
                f.sessions_hit
            );
        }
        for a in &out.advisories {
            println!(
                "recovery on : t={:.0}s  advisory on host {} ({:.1} MB/s observed vs \
                 {:.1} MB/s expected, below since t={:.0}s)",
                a.at_secs,
                a.host,
                a.observed_bps / 1e6,
                a.expected_bps / 1e6,
                a.below_since_secs
            );
        }
        for m in &out.migrations {
            println!(
                "recovery on : t={:.0}s  {} evacuated {} -> {} ({:.1} GB done, \
                 {:.1} GB re-admitted, drain {:.0} s)",
                m.t_secs,
                m.session,
                m.from,
                m.to,
                m.moved_bytes / 1e9,
                m.remaining_bytes / 1e9,
                m.drain_secs
            );
        }
        for d in &out.fleet.dead_letters {
            println!(
                "recovery off: {} quarantined ({}, attempt {}, {:.1} GB delivered, \
                 {:.1} GB owed)",
                d.session,
                d.reason.id(),
                d.attempts,
                d.moved_bytes / 1e9,
                d.remaining_bytes / 1e9
            );
        }
    }
    println!("\n{}", table.to_markdown());

    println!("== crash and revival: the retry pipeline on one host ==\n");
    let faults = FaultSchedule::default().with_host_failure(
        0,
        SimTime::from_secs(30.0),
        Some(SimTime::from_secs(120.0)),
    );
    let cfg = DispatcherConfig::new(
        vec![HostSpec::new("lone", testbeds::cloudlab()).with_max_sessions(1)],
        PlacementKind::MarginalEnergy,
    )
    .with_sessions(vec![SessionSpec::new(
        "survivor",
        standard::medium_dataset(7),
        AlgorithmKind::MaxThroughput,
    )])
    .with_seed(42)
    .with_resilience(ResilienceConfig::new().with_recovery().with_faults(faults));
    let out = run_dispatcher(&cfg);
    for r in &out.retries {
        println!(
            "t={:.0}s  {} lost on {} (attempt {}), backoff {:.0} s, resumes at t={:.0}s \
             with {:.1} GB to re-send",
            r.t_secs,
            r.session,
            r.from,
            r.attempt,
            r.backoff_secs,
            r.resume_at_secs,
            r.remaining_bytes / 1e9
        );
    }
    let fleet = &out.fleet;
    assert!(fleet.completed, "the survivor must finish after the revival");
    println!(
        "\nsurvivor finished: {:.2} GB delivered in {:.0} s across {} residencies \
         ({} dead-lettered)",
        fleet.moved.as_f64() / 1e9,
        fleet.duration.as_secs(),
        fleet.tenants.len(),
        fleet.dead_letters.len()
    );
    println!(
        "the remainder was re-sent from scratch on the revived host — delivered bytes\n\
         stay delivered, lost in-flight bytes are re-materialized, and the fleet's\n\
         outcome accounts for every admitted byte."
    );
}
