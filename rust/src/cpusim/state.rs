//! Mutable CPU setting: active cores + current P-state.

use super::CpuSpec;
use crate::units::Freq;

/// The knobs Algorithm 3 actuates: which P-state the active cores run at
/// and how many cores are online. Transitions move one step at a time,
/// mirroring the paper's `increaseFrequency()` / `decreaseActiveCores()`
/// primitives.
#[derive(Debug, Clone)]
pub struct CpuState {
    spec: CpuSpec,
    active_cores: u32,
    freq_index: usize,
}

impl CpuState {
    /// Start at a given setting (clamped into the valid range).
    pub fn new(spec: CpuSpec, active_cores: u32, freq: Freq) -> Self {
        let freq_index = spec
            .freq_levels
            .iter()
            .position(|&f| f >= freq)
            .unwrap_or(spec.freq_levels.len() - 1);
        let active_cores = active_cores.clamp(1, spec.num_cores);
        CpuState { spec, active_cores, freq_index }
    }

    /// SLA(Energy) initial setting (Alg. 1 lines 14-16): 1 core, min freq.
    pub fn min_energy_start(spec: CpuSpec) -> Self {
        CpuState { active_cores: 1, freq_index: 0, spec }
    }

    /// SLA(Throughput) initial setting (Alg. 1 lines 17-19): all cores,
    /// min frequency (Alg. 3 ramps frequency up only if load demands it).
    pub fn max_throughput_start(spec: CpuSpec) -> Self {
        CpuState { active_cores: spec.num_cores, freq_index: 0, spec }
    }

    /// Baseline governor: everything on, maximum frequency (what the
    /// comparison tools run under — no scaling).
    pub fn performance(spec: CpuSpec) -> Self {
        CpuState {
            active_cores: spec.num_cores,
            freq_index: spec.freq_levels.len() - 1,
            spec,
        }
    }

    /// The CPU model this setting runs on.
    pub fn spec(&self) -> &CpuSpec {
        &self.spec
    }

    /// Cores currently online.
    pub fn active_cores(&self) -> u32 {
        self.active_cores
    }

    /// Current core frequency.
    pub fn freq(&self) -> Freq {
        self.spec.freq_levels[self.freq_index]
    }

    /// Index into the P-state ladder. Together with [`Self::active_cores`]
    /// this is a cheap equality key for operating-point caches (two
    /// integer compares instead of hashing the frequency).
    pub fn freq_index(&self) -> usize {
        self.freq_index
    }

    /// True at the top P-state.
    pub fn at_max_freq(&self) -> bool {
        self.freq_index + 1 == self.spec.freq_levels.len()
    }

    /// True at the bottom P-state.
    pub fn at_min_freq(&self) -> bool {
        self.freq_index == 0
    }

    /// True with every core online.
    pub fn at_max_cores(&self) -> bool {
        self.active_cores == self.spec.num_cores
    }

    /// True with a single core online.
    pub fn at_min_cores(&self) -> bool {
        self.active_cores == 1
    }

    /// `increaseActiveCores()` — one core, saturating.
    pub fn increase_cores(&mut self) -> bool {
        if self.at_max_cores() {
            false
        } else {
            self.active_cores += 1;
            true
        }
    }

    /// `decreaseActiveCores()` — one core, floor 1.
    pub fn decrease_cores(&mut self) -> bool {
        if self.at_min_cores() {
            false
        } else {
            self.active_cores -= 1;
            true
        }
    }

    /// `increaseFrequency()` — one P-state up, saturating.
    pub fn increase_freq(&mut self) -> bool {
        if self.at_max_freq() {
            false
        } else {
            self.freq_index += 1;
            true
        }
    }

    /// `decreaseFrequency()` — one P-state down, saturating.
    pub fn decrease_freq(&mut self) -> bool {
        if self.at_min_freq() {
            false
        } else {
            self.freq_index -= 1;
            true
        }
    }

    /// Jump directly to a setting (used by the predictive governor, which
    /// picks a whole operating point rather than stepping). Clamped to the
    /// valid range; frequency snaps to the nearest ladder level at or
    /// above the request.
    pub fn apply(&mut self, active_cores: u32, freq: Freq) {
        self.active_cores = active_cores.clamp(1, self.spec.num_cores);
        self.freq_index = self
            .spec
            .freq_levels
            .iter()
            .position(|&f| f.as_hz() >= freq.as_hz() - 1.0)
            .unwrap_or(self.spec.freq_levels.len() - 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cpusim::standard::haswell_server;

    #[test]
    fn starts_clamped() {
        let s = CpuState::new(haswell_server(), 99, Freq::from_ghz(99.0));
        assert_eq!(s.active_cores(), 8);
        assert!(s.at_max_freq());
        let s = CpuState::new(haswell_server(), 0, Freq::ZERO);
        assert_eq!(s.active_cores(), 1);
        assert!(s.at_min_freq());
    }

    #[test]
    fn sla_starts_match_algorithm1() {
        let e = CpuState::min_energy_start(haswell_server());
        assert_eq!(e.active_cores(), 1);
        assert!(e.at_min_freq());
        let t = CpuState::max_throughput_start(haswell_server());
        assert_eq!(t.active_cores(), 8);
        assert!(t.at_min_freq());
    }

    #[test]
    fn performance_governor_is_maxed() {
        let p = CpuState::performance(haswell_server());
        assert!(p.at_max_cores() && p.at_max_freq());
    }

    #[test]
    fn steps_saturate() {
        let mut s = CpuState::min_energy_start(haswell_server());
        assert!(!s.decrease_freq());
        assert!(!s.decrease_cores());
        for _ in 0..100 {
            s.increase_freq();
            s.increase_cores();
        }
        assert!(s.at_max_freq() && s.at_max_cores());
        assert!(!s.increase_freq());
        assert!(!s.increase_cores());
    }

    #[test]
    fn freq_moves_one_level() {
        let mut s = CpuState::min_energy_start(haswell_server());
        let f0 = s.freq();
        s.increase_freq();
        let f1 = s.freq();
        assert!((f1.as_ghz() - f0.as_ghz() - 0.2).abs() < 1e-9);
    }
}
