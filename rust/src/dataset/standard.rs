//! The paper's four benchmark datasets (Table II).
//!
//! | Dataset | Num files | Total size | Avg file size | Std dev |
//! |---------|-----------|------------|---------------|---------|
//! | Small   | 20,000    | 1.94 GB    | 101.92 KB     | 29.06 KB |
//! | Medium  | 5,000     | 11.70 GB   | 2.40 MB       | 0.27 MB |
//! | Large   | 128       | 27.85 GB   | 222.78 MB     | 15.19 MB |
//! | Mixed   | combination of the above three |

use super::{generate, Dataset, DatasetSpec};
use crate::units::Bytes;

/// Table II "Small files": 20,000 files averaging 101.92 KB.
pub fn small_spec() -> DatasetSpec {
    DatasetSpec::new("small", 20_000, Bytes::from_kb(101.92), Bytes::from_kb(29.06))
}

/// Table II "Medium files": 5,000 files averaging 2.40 MB.
pub fn medium_spec() -> DatasetSpec {
    DatasetSpec::new("medium", 5_000, Bytes::from_mb(2.40), Bytes::from_mb(0.27))
}

/// Table II "Large files": 128 files averaging 222.78 MB.
pub fn large_spec() -> DatasetSpec {
    DatasetSpec::new("large", 128, Bytes::from_mb(222.78), Bytes::from_mb(15.19))
}

/// Generate the Table II small-file dataset.
pub fn small_dataset(seed: u64) -> Dataset {
    generate(&small_spec(), seed)
}

/// Generate the Table II medium-file dataset.
pub fn medium_dataset(seed: u64) -> Dataset {
    generate(&medium_spec(), seed)
}

/// Generate the Table II large-file dataset.
pub fn large_dataset(seed: u64) -> Dataset {
    generate(&large_spec(), seed)
}

/// The paper's *mixed* dataset: the three Table II datasets combined.
pub fn mixed_dataset(seed: u64) -> Dataset {
    let s = small_dataset(seed);
    let m = medium_dataset(seed);
    let l = large_dataset(seed);
    Dataset::concat("mixed", &[&s, &m, &l])
}

/// Look a standard dataset up by name (`small|medium|large|mixed`).
pub fn by_name(name: &str, seed: u64) -> Option<Dataset> {
    match name {
        "small" => Some(small_dataset(seed)),
        "medium" => Some(medium_dataset(seed)),
        "large" => Some(large_dataset(seed)),
        "mixed" => Some(mixed_dataset(seed)),
        _ => None,
    }
}

/// All four standard dataset names in paper order.
pub const STANDARD_NAMES: [&str; 4] = ["small", "medium", "large", "mixed"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_matches_table2() {
        let d = small_dataset(42);
        assert_eq!(d.num_files(), 20_000);
        assert!((d.total_size().as_gb() - 1.94).abs() < 0.12, "total {}", d.total_size());
        assert!((d.avg_file_size().as_kb() - 101.92).abs() < 1.0);
    }

    #[test]
    fn medium_matches_table2() {
        let d = medium_dataset(42);
        assert_eq!(d.num_files(), 5_000);
        assert!((d.total_size().as_gb() - 11.70).abs() < 0.5, "total {}", d.total_size());
    }

    #[test]
    fn large_matches_table2() {
        let d = large_dataset(42);
        assert_eq!(d.num_files(), 128);
        assert!((d.total_size().as_gb() - 27.85).abs() < 1.0, "total {}", d.total_size());
    }

    #[test]
    fn mixed_is_the_union() {
        let d = mixed_dataset(42);
        assert_eq!(d.num_files(), 20_000 + 5_000 + 128);
        let expect = small_dataset(42).total_size()
            + medium_dataset(42).total_size()
            + large_dataset(42).total_size();
        assert!((d.total_size().as_f64() - expect.as_f64()).abs() < 1.0);
    }

    #[test]
    fn by_name_resolves_all() {
        for name in STANDARD_NAMES {
            assert!(by_name(name, 1).is_some(), "{name}");
        }
        assert!(by_name("nope", 1).is_none());
    }
}
