//! Fleet hot-path bench: per-tick cost of a multi-tenant world, an
//! allocation audit proving the step path stays allocation-free, and the
//! multi-host dispatcher's decision + end-to-end costs.
//!
//!     cargo bench --bench bench_fleet
//!
//! A counting global allocator wraps the system allocator; after a warmup
//! that sizes every scratch buffer, N steps must perform zero heap
//! allocations — the invariant the scratch-buffer design exists for.

use greendt::benchkit::bench;
use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::cpusim::CpuState;
use greendt::dataset::{partition_files_capped, standard};
use greendt::netsim::CrossTrafficConfig;
use greendt::sim::dispatcher::{
    run_dispatcher, Dispatcher, DispatcherConfig, HostCandidate, HostSpec, SessionSpec,
};
use greendt::sim::Simulation;
use greendt::transfer::TransferEngine;
use greendt::units::{SimDuration, SimTime};

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to the system allocator unchanged;
// the counter is a relaxed atomic with no other side effects.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// A world with `tenants` active large-dataset sessions (large files so no
/// partition completes mid-audit, which would legitimately reopen
/// channels), optionally on a contended link and/or with AIMD channels.
fn fleet_sim_on(
    tenants: usize,
    channels_each: u32,
    cross: Option<CrossTrafficConfig>,
    aimd: bool,
) -> Simulation {
    let tb = testbeds::cloudlab();
    let client = CpuState::performance(tb.client_cpu.clone());
    let tick = SimDuration::from_millis(100.0);
    let mut sim = match cross {
        Some(c) => Simulation::empty_with_cross_traffic(&tb, client, tick, 9, Vec::new(), c),
        None => Simulation::empty(&tb, client, tick, 9, Vec::new()),
    };
    for i in 0..tenants {
        let ds = standard::large_dataset(20 + i as u64);
        let parts = partition_files_capped(&ds, tb.bdp(), 5);
        let mut engine =
            TransferEngine::with_knee(&parts, tb.link.avg_win, tb.link.knee_streams());
        engine.set_num_channels(channels_each);
        engine.set_aimd(aimd);
        let slot = sim.add_slot(engine);
        sim.activate_slot(slot);
    }
    sim
}

/// The quiet baseline world every existing bench runs on.
fn fleet_sim(tenants: usize, channels_each: u32) -> Simulation {
    fleet_sim_on(tenants, channels_each, None, false)
}

fn main() {
    println!("== bench_fleet: multi-tenant step hot path ==\n");

    // Timing across fleet sizes.
    for tenants in [1usize, 4, 16] {
        let mut sim = fleet_sim(tenants, 4);
        bench(&format!("fleet step/{tenants} tenants"), 200, 5000, || sim.step());
    }
    println!();

    // Contended-vs-quiet pair: the generators add a per-tick RNG draw +
    // burst bookkeeping on the link, and AIMD a per-stream window update
    // — this pins what that overhead costs against the same quiet world.
    let cross = CrossTrafficConfig {
        udp_fraction: 0.1,
        tcp_rate_per_sec: 0.3,
        tcp_burst_bytes: 20e6,
        tcp_burst_secs: 1.0,
    };
    let mut quiet = fleet_sim(4, 4);
    bench("fleet step/4 tenants/quiet", 200, 5000, || quiet.step());
    let mut contended = fleet_sim_on(4, 4, Some(cross), false);
    bench("fleet step/4 tenants/contended", 200, 5000, || contended.step());
    let mut contended_aimd = fleet_sim_on(4, 4, Some(cross), true);
    bench("fleet step/4 tenants/contended+aimd", 200, 5000, || contended_aimd.step());
    println!();

    // Allocation audit: warm up (scratch buffers grow to steady-state
    // capacity, TCP windows leave slow start), then count.
    let mut sim = fleet_sim(4, 4);
    for _ in 0..500 {
        sim.step();
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    let steps = 2000u64;
    for _ in 0..steps {
        sim.step();
    }
    let allocs = ALLOCS.load(Ordering::Relaxed) - before;
    println!("allocation audit: {allocs} allocations across {steps} steps (4 tenants)");
    assert_eq!(
        allocs, 0,
        "the fleet step path must stay allocation-free per tick"
    );
    println!("allocation audit passed: step is allocation-free\n");

    // Dispatcher decision cost: pure placement over a synthetic 16-host
    // candidate snapshot (what every arrival pays at dispatch time).
    let candidates: Vec<HostCandidate> = (0..16)
        .map(|i| HostCandidate {
            host: i,
            active_sessions: (i % 5) as u32,
            free_slots: 8 - (i % 5) as u32,
            current_power_w: 20.0 + i as f64,
            projected_power_w: 30.0 + ((i * 7) % 13) as f64,
            projected_session_bps: 40e6 + (i as f64) * 5e6,
            projected_fleet_power_w: 400.0 + i as f64,
            queue_delay_j_per_byte: if i % 2 == 0 { 0.0 } else { 2e-8 },
            learned_j_per_byte: if i % 3 == 0 { Some(1e-7 + i as f64 * 1e-9) } else { None },
            learned_weight: if i % 3 == 0 { 0.6 } else { 0.0 },
        })
        .collect();
    for placement in [
        PlacementKind::RoundRobin,
        PlacementKind::LeastLoaded,
        PlacementKind::MarginalEnergy,
        PlacementKind::Learned,
    ] {
        let mut d = Dispatcher::new(placement, None);
        bench(&format!("dispatcher place/{}/16 hosts", placement.id()), 1000, 200_000, || {
            d.place(&candidates)
        });
    }
    println!();

    // End-to-end dispatcher macro bench: 2 heterogeneous hosts × 4
    // spaced sessions through the cross-host event-horizon loop.
    let mk_cfg = |placement| {
        let hosts = vec![
            HostSpec::new("efficient", testbeds::cloudlab()),
            HostSpec::new("legacy", testbeds::didclab()),
        ];
        let sessions: Vec<SessionSpec> = (0..4u64)
            .map(|i| {
                SessionSpec::new(
                    format!("s{i}"),
                    standard::medium_dataset(50 + i),
                    AlgorithmKind::MaxThroughput,
                )
                .arriving_at(SimTime::from_secs(120.0 * i as f64))
            })
            .collect();
        DispatcherConfig::new(hosts, placement).with_sessions(sessions).with_seed(7)
    };
    for placement in [PlacementKind::RoundRobin, PlacementKind::MarginalEnergy] {
        let cfg = mk_cfg(placement);
        bench(&format!("run_dispatcher/2 hosts/4 sessions/{}", placement.id()), 0, 3, || {
            run_dispatcher(&cfg)
        });
    }
}
