//! Acceptance tests for the historical-log learning subsystem (ISSUE 4).
//!
//! Pins the headline properties:
//!
//! * the `RunRecord` JSONL schema round-trips bit-for-bit through a
//!   file-backed store, and unknown-version lines are skipped with a
//!   count (forward compatibility);
//! * the k-NN index is deterministic under a fixed seed;
//! * `HistoryTuned` without a warm start is bit-for-bit the existing
//!   Minimum Energy slow-start path;
//! * the `examples/learned_fleet.rs` scenario: replaying the same seeded
//!   arrival script warm consumes strictly fewer joules at
//!   equal-or-better aggregate goodput than the cold run.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, FleetPolicyKind, PlacementKind};
use greendt::dataset::standard;
use greendt::history::{
    HistoryStore, KnnIndex, Query, RunOutcome, RunRecord, TrajPoint, WorkloadFingerprint,
};
use greendt::sim::dispatcher::{run_dispatcher, DispatcherConfig, HostSpec};
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::{Rate, SimTime};

/// The `learned_fleet` example's arrival script: N staggered medium
/// tenants on DIDCLab under the min-energy fleet policy, one seed.
fn fleet_cfg(kinds: &[AlgorithmKind]) -> FleetConfig {
    let mut cfg = FleetConfig::new(testbeds::didclab(), Some(FleetPolicyKind::MinEnergyFleet))
        .with_seed(11);
    for (i, kind) in kinds.iter().enumerate() {
        cfg.tenants.push(
            TenantSpec::new(
                format!("tenant-{i}"),
                standard::medium_dataset(11 + i as u64),
                *kind,
            )
            .arriving_at(SimTime::from_secs(40.0 * i as f64)),
        );
    }
    cfg
}

fn goodput(out: &FleetOutcome) -> f64 {
    Rate::average(out.moved, out.duration).as_bytes_per_sec()
}

fn temp_store(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("greendt_it_{name}_{}.jsonl", std::process::id()))
}

#[test]
fn run_record_schema_round_trips_through_a_file() {
    let record = RunRecord {
        session: "tenant-0".to_string(),
        algorithm: "history".to_string(),
        host: "DIDCLab".to_string(),
        testbed: "DIDCLab".to_string(),
        rtt_s: 0.044,
        bandwidth_bps: 1e9,
        workload: WorkloadFingerprint {
            total_bytes: 11.7e9,
            num_files: 5000,
            avg_file_bytes: 2.34e6,
            frac_small: 0.125,
            frac_medium: 0.75,
            frac_large: 0.125,
        },
        contention: 2,
        cores: 2,
        pstate: 1,
        channels: 9,
        peak_channels: 14,
        goodput_bps: 1.0817e8,
        joules: 8123.25,
        j_per_byte: 8123.25 / 11.7e9,
        moved_bytes: 11.7e9,
        duration_s: 108.2,
        completed: true,
        outcome: RunOutcome::Completed,
        admission_marginal_jpb: Some(2.5e-7),
        traj: vec![TrajPoint { t_secs: 3.0, cores: 1, pstate: 0, channels: 6 }],
    };
    let path = temp_store("roundtrip");
    let _ = std::fs::remove_file(&path);
    let mut store = HistoryStore::open(&path).unwrap();
    store.append_runs(std::slice::from_ref(&record)).unwrap();

    let back = HistoryStore::open(&path).unwrap();
    assert_eq!(back.runs().len(), 1);
    assert_eq!(back.skipped(), 0);
    let b = &back.runs()[0];
    assert_eq!(b, &record, "serialize → load must be identical");
    // f64 fields survive bit-for-bit (shortest round-trip rendering).
    assert_eq!(b.j_per_byte.to_bits(), record.j_per_byte.to_bits());
    assert_eq!(b.goodput_bps.to_bits(), record.goodput_bps.to_bits());
    let _ = std::fs::remove_file(&path);
}

#[test]
fn unknown_version_lines_are_skipped_with_a_count() {
    let good = RunRecord {
        session: "ok".to_string(),
        algorithm: "me".to_string(),
        host: "h".to_string(),
        testbed: "CloudLab".to_string(),
        rtt_s: 0.036,
        bandwidth_bps: 1e9,
        workload: WorkloadFingerprint {
            total_bytes: 2e9,
            num_files: 100,
            avg_file_bytes: 2e7,
            frac_small: 0.0,
            frac_medium: 1.0,
            frac_large: 0.0,
        },
        contention: 0,
        cores: 1,
        pstate: 0,
        channels: 4,
        peak_channels: 4,
        goodput_bps: 1e8,
        joules: 100.0,
        j_per_byte: 5e-8,
        moved_bytes: 2e9,
        duration_s: 20.0,
        completed: true,
        outcome: RunOutcome::Completed,
        admission_marginal_jpb: None,
        traj: Vec::new(),
    }
    .to_json_line();
    // A legacy v1 writer's line: no "adm_jpb" or "outcome" key, version
    // stamp 1 — still a *known* version, so it must load (fields
    // defaulted: marginal unset, outcome derived from "completed").
    let legacy = good
        .replace("\"adm_jpb\":null,", "")
        .replace("\"outcome\":\"completed\",", "")
        .replace("\"v\":3,", "\"v\":1,");
    let future = good.replace("\"v\":3,", "\"v\":999,");
    let path = temp_store("skip");
    std::fs::write(&path, format!("{good}\n{legacy}\n{future}\nnot json\n")).unwrap();
    let store = HistoryStore::open(&path).unwrap();
    assert_eq!(store.runs().len(), 2, "the v3 and legacy v1 lines both load");
    assert_eq!(store.runs()[1].admission_marginal_jpb, None);
    assert_eq!(store.runs()[1].outcome, RunOutcome::Completed);
    assert_eq!(store.skipped(), 2, "unknown version + garbage are counted");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn knn_answers_are_pinned_under_a_fixed_seed() {
    // Records come from a real (seeded, deterministic) cold fleet run, so
    // this pins the whole record→index→answer pipeline.
    let kinds = vec![AlgorithmKind::HistoryTuned(None); 2];
    let a = run_fleet(&fleet_cfg(&kinds));
    let b = run_fleet(&fleet_cfg(&kinds));
    assert_eq!(a.run_records.len(), 2);
    for (x, y) in a.run_records.iter().zip(&b.run_records) {
        assert_eq!(x, y, "records must be reproducible under the seed");
    }
    let q = Query::on_testbed(
        &testbeds::didclab(),
        WorkloadFingerprint::of(&standard::medium_dataset(11)),
        0,
    )
    .with_algorithm("history");
    let wa = KnnIndex::build(&a.run_records).warm_start(&q);
    let wb = KnnIndex::build(&b.run_records).warm_start(&q);
    assert_eq!(wa, wb, "same records, same answer");
    let (warm, confidence) = wa.expect("two records indexed");
    assert!(confidence >= greendt::history::CONFIDENCE_FLOOR, "confidence {confidence}");
    assert!(warm.cores >= 1 && warm.channels >= 1);
}

#[test]
fn history_tuned_cold_is_bit_for_bit_the_slow_start_path() {
    let mk = |kind| {
        SessionConfig::new(testbeds::didclab(), standard::medium_dataset(6), kind)
            .with_seed(77)
    };
    let me = run_session(&mk(AlgorithmKind::MinEnergy));
    let cold = run_session(&mk(AlgorithmKind::HistoryTuned(None)));
    assert!(me.completed && cold.completed);
    assert_eq!(
        me.duration.as_secs().to_bits(),
        cold.duration.as_secs().to_bits(),
        "cold fallback must reproduce ME's timing exactly"
    );
    assert_eq!(
        me.client_energy.as_joules().to_bits(),
        cold.client_energy.as_joules().to_bits(),
        "cold fallback must reproduce ME's energy exactly"
    );
    assert_eq!(me.peak_channels, cold.peak_channels);
    assert_eq!(me.final_active_cores, cold.final_active_cores);
}

#[test]
fn warm_replay_beats_the_cold_run_on_the_same_arrival_script() {
    // The examples/learned_fleet.rs scenario, pinned.
    let cold_kinds = vec![AlgorithmKind::HistoryTuned(None); 3];
    let cold = run_fleet(&fleet_cfg(&cold_kinds));
    assert!(cold.completed, "cold run must finish");
    assert_eq!(cold.run_records.len(), 3);

    let mut store = HistoryStore::in_memory();
    store.append_runs(&cold.run_records).unwrap();
    let index = store.index();
    let tb = testbeds::didclab();
    let warm_kinds: Vec<AlgorithmKind> = (0..3u64)
        .map(|i| {
            let fp = WorkloadFingerprint::of(&standard::medium_dataset(11 + i));
            let q = Query::on_testbed(&tb, fp, i as u32).with_algorithm("history");
            let warm = index
                .confident_warm_start(&q)
                .expect("a store of identical workloads must answer confidently");
            AlgorithmKind::HistoryTuned(Some(warm))
        })
        .collect();
    let warm = run_fleet(&fleet_cfg(&warm_kinds));
    assert!(warm.completed, "warm run must finish");

    // Headline: strictly fewer joules at equal-or-better goodput.
    let cold_j = cold.client_energy.as_joules();
    let warm_j = warm.client_energy.as_joules();
    assert!(
        warm_j < cold_j,
        "warm replay must consume strictly fewer joules: {warm_j:.0} vs {cold_j:.0}"
    );
    assert!(
        goodput(&warm) >= goodput(&cold),
        "warm replay must not lose aggregate goodput: {} vs {}",
        goodput(&warm),
        goodput(&cold)
    );
    // Same bytes either way — the win is makespan/energy, not volume.
    assert!((warm.moved.as_f64() - cold.moved.as_f64()).abs() < 1.0);
}

#[test]
fn learned_placement_runs_end_to_end_with_recorded_history() {
    // Seed the store from a round-robin run that exercises both hosts,
    // then dispatch the same workload under learned placement.
    let hosts = || {
        vec![
            HostSpec::new("efficient", testbeds::cloudlab()),
            HostSpec::new("legacy", testbeds::didclab()),
        ]
    };
    let sessions = |seed0: u64| -> Vec<TenantSpec> {
        (0..4u64)
            .map(|i| {
                TenantSpec::new(
                    format!("session-{i}"),
                    standard::medium_dataset(seed0 + i),
                    AlgorithmKind::MaxThroughput,
                )
                .arriving_at(SimTime::from_secs(180.0 * i as f64))
            })
            .collect()
    };
    let seed_run = run_dispatcher(
        &DispatcherConfig::new(hosts(), PlacementKind::RoundRobin)
            .with_sessions(sessions(100))
            .with_seed(17),
    );
    assert!(seed_run.fleet.completed);
    let mut store = HistoryStore::in_memory();
    store.append_runs(&seed_run.fleet.run_records).unwrap();
    store.append_dispatches(&seed_run.decisions).unwrap();
    assert_eq!(store.stats().runs, 4);
    assert_eq!(store.stats().dispatches, 4);

    let learned = run_dispatcher(
        &DispatcherConfig::new(hosts(), PlacementKind::Learned)
            .with_sessions(sessions(100))
            .with_seed(17)
            .with_history(store.index()),
    );
    assert!(learned.fleet.completed);
    assert!(learned.unplaced.is_empty());
    // The decisions carry the observed costs the blend used, and with
    // history from both hosts the efficient one must win every spaced
    // placement (it wins on both the model and the observed term).
    for d in &learned.decisions {
        assert!(
            d.scores.iter().any(|s| s.learned_j_per_byte.is_some()),
            "learned placement must surface observed costs in telemetry"
        );
        assert_eq!(d.host.as_deref(), Some("efficient"));
    }
    // And it never does worse than the model-only score on energy here.
    let me_run = run_dispatcher(
        &DispatcherConfig::new(hosts(), PlacementKind::MarginalEnergy)
            .with_sessions(sessions(100))
            .with_seed(17),
    );
    assert!(
        learned.fleet.client_energy.as_joules()
            <= me_run.fleet.client_energy.as_joules() + 1e-6,
        "learned placement must not regress marginal energy on this fleet"
    );
}
