//! The end-to-end simulation: WAN + two end systems + transfer engines.
//!
//! [`Simulation`] advances the whole world — one shared client [`Host`]
//! running N tenant [`SessionSlot`]s — one tick at a time; [`session`]
//! runs a single complete transfer under a tuning algorithm and produces
//! a [`session::SessionOutcome`] (the numbers the paper's figures plot);
//! [`fleet`] drives N concurrent sessions with cross-session arbitration
//! and per-tenant accounting; [`dispatcher`] drives several hosts behind
//! a placement policy with open (Poisson) workloads and power-capped
//! admission control. The session driver is the N=1 special case of the
//! fleet driver, which in turn is the one-host special case of the
//! dispatcher's per-host world.

mod engine;
mod host;
mod telemetry;
pub mod dispatcher;
pub mod fleet;
pub mod session;

pub use engine::{SessionSlot, Simulation, TuneCtx};
pub use host::{FleetView, Host, HostTick, ProjectedPoint, MAX_APP_UTILIZATION};
pub use fleet::FleetOutcome;
pub use telemetry::{
    DispatchRecord, FaultRecord, MigrationRecord, NetView, PlacementScore, RetryRecord,
    Telemetry, TickStats,
};
