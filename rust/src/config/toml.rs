//! A small TOML-subset parser.
//!
//! The offline crate set has no `serde`/`toml`, so GreenDT parses its own
//! config files. Supported subset (everything the CLI's `--config` files
//! need):
//!
//! * `[table]` and `[table.subtable]` headers,
//! * `key = value` with string (`"…"`), boolean, integer, float values,
//! * homogeneous arrays of the above (`[1, 2, 3]`),
//! * `#` comments and blank lines.
//!
//! Values are exposed as a flat map from dotted path (`table.key`) to
//! [`Value`]; helpers perform checked typed access.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed TOML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// String value.
    Str(String),
    /// Integer value.
    Int(i64),
    /// Float value.
    Float(f64),
    /// Boolean value.
    Bool(bool),
    /// Homogeneous array of values.
    Array(Vec<Value>),
}

impl Value {
    /// The string payload, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The integer payload, if this is an integer.
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Floats accept integer literals too (`42` is a valid float value).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// The boolean payload, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The element slice, if this is an array.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse error with line information.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// 1-based line the error was detected on.
    pub line: usize,
    /// What went wrong.
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "line {}: {}", self.line, self.message)
    }
}

impl std::error::Error for ParseError {}

/// A parsed document: dotted-path → value.
#[derive(Debug, Clone, Default)]
pub struct Document {
    values: BTreeMap<String, Value>,
}

impl Document {
    /// Parse a TOML document from source text.
    pub fn parse(input: &str) -> Result<Document, ParseError> {
        let mut values = BTreeMap::new();
        let mut prefix = String::new();
        for (idx, raw) in input.lines().enumerate() {
            let lineno = idx + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| ParseError {
                    line: lineno,
                    message: "unterminated table header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() || !name.chars().all(is_key_char_or_dot) {
                    return Err(ParseError {
                        line: lineno,
                        message: format!("invalid table name '{name}'"),
                    });
                }
                prefix = name.to_string();
                continue;
            }
            let eq = line.find('=').ok_or_else(|| ParseError {
                line: lineno,
                message: "expected 'key = value'".into(),
            })?;
            let key = line[..eq].trim();
            if key.is_empty() || !key.chars().all(is_key_char) {
                return Err(ParseError { line: lineno, message: format!("invalid key '{key}'") });
            }
            let value = parse_value(line[eq + 1..].trim(), lineno)?;
            let path =
                if prefix.is_empty() { key.to_string() } else { format!("{prefix}.{key}") };
            if values.insert(path.clone(), value).is_some() {
                return Err(ParseError { line: lineno, message: format!("duplicate key '{path}'") });
            }
        }
        Ok(Document { values })
    }

    /// Look a value up by dotted path (`table.key`).
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.values.get(path)
    }

    /// Typed lookup: string at `path`.
    pub fn get_str(&self, path: &str) -> Option<&str> {
        self.get(path).and_then(Value::as_str)
    }

    /// Typed lookup: integer at `path`.
    pub fn get_int(&self, path: &str) -> Option<i64> {
        self.get(path).and_then(Value::as_int)
    }

    /// Typed lookup: float at `path`.
    pub fn get_float(&self, path: &str) -> Option<f64> {
        self.get(path).and_then(Value::as_float)
    }

    /// Typed lookup: boolean at `path`.
    pub fn get_bool(&self, path: &str) -> Option<bool> {
        self.get(path).and_then(Value::as_bool)
    }

    /// Iterate all (path, value) pairs (sorted by path).
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Value)> {
        self.values.iter()
    }

    /// Number of keys in the document.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True when the document has no keys.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn is_key_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_' || c == '-'
}

fn is_key_char_or_dot(c: char) -> bool {
    is_key_char(c) || c == '.'
}

/// Strip a `#` comment, respecting quoted strings.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    let err = |m: String| ParseError { line, message: m };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        if inner.contains('"') {
            return Err(err("embedded quotes are not supported".into()));
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(rest) = s.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| err("unterminated array".into()))?;
        let inner = inner.trim();
        if inner.is_empty() {
            return Ok(Value::Array(Vec::new()));
        }
        let items = inner
            .split(',')
            .map(|item| parse_value(item.trim(), line))
            .collect::<Result<Vec<_>, _>>()?;
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_keys() {
        let doc = Document::parse("a = 1\nb = 2.5\nc = \"hi\"\nd = true\n").unwrap();
        assert_eq!(doc.get_int("a"), Some(1));
        assert_eq!(doc.get_float("b"), Some(2.5));
        assert_eq!(doc.get_str("c"), Some("hi"));
        assert_eq!(doc.get_bool("d"), Some(true));
        assert_eq!(doc.len(), 4);
    }

    #[test]
    fn tables_prefix_keys() {
        let doc = Document::parse("[tuner]\nalpha = 0.1\n[tuner.nested]\nx = 2\n").unwrap();
        assert_eq!(doc.get_float("tuner.alpha"), Some(0.1));
        assert_eq!(doc.get_int("tuner.nested.x"), Some(2));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let doc = Document::parse("# header\n\na = 1 # trailing\nb = \"x # not comment\"\n")
            .unwrap();
        assert_eq!(doc.get_int("a"), Some(1));
        assert_eq!(doc.get_str("b"), Some("x # not comment"));
    }

    #[test]
    fn arrays() {
        let doc = Document::parse("xs = [1, 2, 3]\nys = [0.5, 1.5]\nempty = []\n").unwrap();
        let xs = doc.get("xs").unwrap().as_array().unwrap();
        assert_eq!(xs.len(), 3);
        assert_eq!(xs[2].as_int(), Some(3));
        assert_eq!(doc.get("empty").unwrap().as_array().unwrap().len(), 0);
    }

    #[test]
    fn int_accepted_as_float() {
        let doc = Document::parse("x = 3\n").unwrap();
        assert_eq!(doc.get_float("x"), Some(3.0));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Document::parse("a = 1\nbad line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = Document::parse("a = \"unterminated\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = Document::parse("[unclosed\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn duplicate_keys_rejected() {
        let e = Document::parse("a = 1\na = 2\n").unwrap_err();
        assert!(e.message.contains("duplicate"));
    }

    #[test]
    fn negative_and_scientific_numbers() {
        let doc = Document::parse("a = -5\nb = 1e9\nc = -0.25\n").unwrap();
        assert_eq!(doc.get_int("a"), Some(-5));
        assert_eq!(doc.get_float("b"), Some(1e9));
        assert_eq!(doc.get_float("c"), Some(-0.25));
    }
}
