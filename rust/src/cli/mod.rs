//! Command-line interface (hand-rolled; the offline crate set has no clap).

mod args;
mod logger;
mod commands;

pub use args::{ArgError, ParsedArgs};
pub use commands::{run, USAGE};
pub use logger::init_logger;
