//! Cross-module integration: whole sessions on real testbed + dataset
//! combinations, exercising coordinator + transfer + netsim + cpusim +
//! power together.

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::Rate;

fn run(tb: &str, ds: &str, kind: AlgorithmKind) -> greendt::sim::session::SessionOutcome {
    let cfg = SessionConfig::new(
        testbeds::by_name(tb).unwrap(),
        standard::by_name(ds, 42).unwrap(),
        kind,
    );
    run_session(&cfg)
}

#[test]
fn every_algorithm_completes_on_every_testbed() {
    // wget/curl excluded here only for wall-time (they are covered by the
    // fig2 grid test); everything else must finish on every testbed.
    let kinds = [
        AlgorithmKind::MinEnergy,
        AlgorithmKind::MaxThroughput,
        AlgorithmKind::TargetThroughput(Rate::from_mbps(400.0)),
        AlgorithmKind::Http2,
        AlgorithmKind::IsmailMinEnergy,
        AlgorithmKind::IsmailMaxThroughput,
        AlgorithmKind::IsmailTarget(Rate::from_mbps(400.0)),
        AlgorithmKind::AlanMinEnergy,
        AlgorithmKind::AlanMaxThroughput,
    ];
    for tb in ["chameleon", "cloudlab", "didclab"] {
        for kind in kinds {
            let out = run(tb, "large", kind);
            assert!(out.completed, "{} on {tb} did not complete", out.algorithm);
            assert!(out.moved.as_gb() > 27.0, "{} moved {}", out.algorithm, out.moved);
            assert!(out.client_energy.as_joules() > 0.0);
            assert!(out.server_energy.as_joules() > 0.0);
        }
    }
}

#[test]
fn energy_is_power_integral() {
    // client energy ≈ duration × average power, where average power must
    // lie inside the model's physical envelope for that CPU.
    let out = run("cloudlab", "medium", AlgorithmKind::MaxThroughput);
    let avg_w = out.client_package_energy.as_joules() / out.duration.as_secs();
    let pm = greendt::power::standard_power(&testbeds::cloudlab().client_cpu);
    assert!(avg_w >= pm.floor_power().as_watts() * 0.99, "avg {avg_w} W below floor");
    assert!(avg_w <= pm.max_power().as_watts() * 1.01, "avg {avg_w} W above max");
}

#[test]
fn eemt_is_fastest_me_is_cheapest_on_chameleon() {
    let me = run("chameleon", "mixed", AlgorithmKind::MinEnergy);
    let eemt = run("chameleon", "mixed", AlgorithmKind::MaxThroughput);
    let h2 = run("chameleon", "mixed", AlgorithmKind::Http2);
    assert!(eemt.avg_throughput.as_gbps() >= me.avg_throughput.as_gbps() * 0.95);
    assert!(eemt.avg_throughput.as_gbps() > 4.0 * h2.avg_throughput.as_gbps());
    assert!(me.client_energy.as_joules() <= eemt.client_energy.as_joules() * 1.05);
    assert!(me.client_energy.as_joules() < 0.2 * h2.client_energy.as_joules());
}

#[test]
fn eett_energy_scales_inversely_with_target() {
    // Slower targets take longer => more client energy (the race-to-idle
    // regime of this workload), while higher targets finish cheaper.
    let lo = run("cloudlab", "large", AlgorithmKind::TargetThroughput(Rate::from_mbps(200.0)));
    let hi = run("cloudlab", "large", AlgorithmKind::TargetThroughput(Rate::from_mbps(800.0)));
    assert!(lo.completed && hi.completed);
    assert!(lo.duration.as_secs() > 2.0 * hi.duration.as_secs());
    assert!(lo.client_energy.as_joules() > hi.client_energy.as_joules());
}

#[test]
fn dvfs_lowers_energy_vs_os_governor() {
    use greendt::config::experiment::TunerParams;
    let base = SessionConfig::new(
        testbeds::cloudlab(),
        standard::mixed_dataset(42),
        AlgorithmKind::MaxThroughput,
    );
    let with_scaling = run_session(&base.clone());
    let without = run_session(&base.with_params(TunerParams::default().without_scaling()));
    assert!(with_scaling.completed && without.completed);
    assert!(
        with_scaling.client_energy.as_joules() < 0.8 * without.client_energy.as_joules(),
        "scaling {} vs os {}",
        with_scaling.client_energy,
        without.client_energy
    );
    // …without giving up meaningful throughput.
    assert!(
        with_scaling.avg_throughput.as_bits_per_sec()
            > 0.93 * without.avg_throughput.as_bits_per_sec()
    );
}

#[test]
fn predictive_governor_session_works_with_oracle_fallback() {
    use greendt::config::experiment::TunerParams;
    // Point the artifact path somewhere invalid: the governor must fall
    // back to the Rust oracle and the session must still complete.
    std::env::set_var("GREENDT_PREDICTOR", "/nonexistent/predictor.hlo.txt");
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::large_dataset(42),
        AlgorithmKind::MinEnergy,
    )
    .with_params(TunerParams::default().predictive());
    let out = run_session(&cfg);
    std::env::remove_var("GREENDT_PREDICTOR");
    assert!(out.completed);
    assert!(out.final_active_cores <= 3, "predictive ME should downscale");
}

#[test]
fn wall_meter_exceeds_rapl_on_didclab_only() {
    let d = run("didclab", "large", AlgorithmKind::MaxThroughput);
    assert!(d.client_energy.as_joules() > d.client_package_energy.as_joules());
    let c = run("cloudlab", "large", AlgorithmKind::MaxThroughput);
    assert_eq!(c.client_energy.as_joules(), c.client_package_energy.as_joules());
}

#[test]
fn server_scaling_extension_cuts_server_energy() {
    // GreenDT extension: Algorithm 3 applied to the server as well. On a
    // 1 Gbps path the 8-core Haswell server is mostly idle at max
    // frequency; scaling it must cut server energy substantially without
    // hurting throughput.
    let base = SessionConfig::new(
        testbeds::cloudlab(),
        standard::large_dataset(42),
        AlgorithmKind::MaxThroughput,
    );
    let plain = run_session(&base.clone());
    let scaled = run_session(&base.with_server_scaling());
    assert!(plain.completed && scaled.completed);
    assert!(
        scaled.server_energy.as_joules() < 0.75 * plain.server_energy.as_joules(),
        "server scaling: {} vs {}",
        scaled.server_energy,
        plain.server_energy
    );
    assert!(
        scaled.avg_throughput.as_bits_per_sec()
            > 0.95 * plain.avg_throughput.as_bits_per_sec(),
        "throughput preserved: {} vs {}",
        scaled.avg_throughput,
        plain.avg_throughput
    );
}
