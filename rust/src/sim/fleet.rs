//! Fleet driver: N concurrent transfer sessions on one shared host.
//!
//! Each tenant brings its own dataset and tuning algorithm; the world
//! shares one client CPU package, one power budget and one bottleneck
//! link. Tenants arrive on a scripted schedule, tune their own channel
//! counts at their own timeouts, and depart when their transfer
//! completes. A [`FleetPolicy`] arbitrates the *host-level* knobs (active
//! cores, frequency, per-session channel budget) on aggregate telemetry;
//! per-session CPU governors are disabled while a policy is in charge.
//!
//! [`super::session::run_session`] is exactly this driver with one
//! tenant, no policy, and the session's own governor left enabled.

use crate::config::experiment::{GovernorKind, TunerParams};
use crate::config::Testbed;
use crate::coordinator::fleet::{FleetPolicy, FleetPolicyKind};
use crate::coordinator::{Algorithm, AlgorithmKind};
use crate::cpusim::CpuState;
use crate::dataset::Dataset;
use crate::netsim::BandwidthEvent;
use crate::sim::{Simulation, TuneCtx};
use crate::transfer::TransferEngine;
use crate::units::{Bytes, Energy, Freq, Rate, SimDuration, SimTime};

use super::session::TimelinePoint;

/// One tenant: a dataset to move, an algorithm to tune it, an arrival
/// time on the shared host.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    pub dataset: Dataset,
    pub algorithm: AlgorithmKind,
    /// When this session is admitted (simulated clock).
    pub arrive_at: SimTime,
}

impl TenantSpec {
    pub fn new(name: impl Into<String>, dataset: Dataset, algorithm: AlgorithmKind) -> Self {
        TenantSpec { name: name.into(), dataset, algorithm, arrive_at: SimTime::ZERO }
    }

    pub fn arriving_at(mut self, at: SimTime) -> Self {
        self.arrive_at = at;
        self
    }
}

/// Everything needed to run one multi-tenant world.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub testbed: Testbed,
    pub tenants: Vec<TenantSpec>,
    /// Host-level arbitration. `None` leaves the host knobs to the
    /// tenants' own governors (the single-session compatibility mode).
    pub policy: Option<FleetPolicyKind>,
    /// Tuner knobs shared by every tenant's algorithm.
    pub params: TunerParams,
    /// Arbitration cadence of the fleet policy.
    pub fleet_interval: SimDuration,
    pub seed: u64,
    pub tick: SimDuration,
    /// Abort the run after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Record a per-timeout timeline for every tenant (costs memory).
    pub record_timeline: bool,
    /// Scripted background-traffic events (failure injection).
    pub bandwidth_events: Vec<BandwidthEvent>,
    /// GreenDT extension: Algorithm-3 scaling on the *server* too.
    pub server_scaling: bool,
    /// Drive the world with the naive per-tick reference stepper
    /// ([`Simulation::step_reference`]) instead of the epoch-cached fast
    /// path — the oracle the stepper-equivalence tests pin against, and
    /// the baseline `bench_hotpath` reports speedup over.
    pub reference_stepper: bool,
}

impl FleetConfig {
    pub fn new(testbed: Testbed, policy: Option<FleetPolicyKind>) -> Self {
        FleetConfig {
            testbed,
            tenants: Vec::new(),
            policy,
            params: TunerParams::default(),
            fleet_interval: SimDuration::from_secs(3.0),
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            record_timeline: false,
            bandwidth_events: Vec::new(),
            server_scaling: false,
            reference_stepper: false,
        }
    }

    pub fn with_tenant(mut self, tenant: TenantSpec) -> Self {
        self.tenants.push(tenant);
        self
    }

    pub fn with_params(mut self, params: TunerParams) -> Self {
        self.params = params;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// What one tenant got out of the shared host.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    pub name: String,
    pub algorithm: String,
    pub completed: bool,
    pub arrived_at: SimTime,
    pub finished_at: Option<SimTime>,
    pub moved: Bytes,
    /// Average throughput over the tenant's residency on the host.
    pub avg_throughput: Rate,
    /// Time the tenant spent on the host (until it finished, or until the
    /// run's time cap for an unfinished tenant).
    pub residency: SimDuration,
    /// Host instrument energy attributed to this tenant: its share of
    /// every tick's draw while resident, weighted by bytes moved (ticks
    /// where nothing moved split evenly among resident tenants). Ticks
    /// with *no* resident session are host idle overhead attributed to
    /// nobody, so the tenant shares sum to the host bill only when the
    /// arrival schedule leaves no gaps.
    pub attributed_energy: Energy,
    /// Client package (RAPL) energy attributed to this tenant.
    pub attributed_package_energy: Energy,
    pub peak_channels: u32,
    pub timeline: Vec<TimelinePoint>,
}

/// What the whole fleet run produced.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    pub policy: String,
    pub tenants: Vec<TenantOutcome>,
    pub completed: bool,
    pub duration: SimDuration,
    pub moved: Bytes,
    /// Host client energy per the testbed's instrument (RAPL or wall).
    pub client_energy: Energy,
    pub client_package_energy: Energy,
    pub server_energy: Energy,
    pub final_active_cores: u32,
    pub final_freq: Freq,
}

impl FleetOutcome {
    /// Host energy divided by tenant count — the fleet-level figure of
    /// merit (energy bill per served session).
    pub fn energy_per_tenant(&self) -> Energy {
        Energy::from_joules(
            self.client_energy.as_joules() / self.tenants.len().max(1) as f64,
        )
    }
}

/// Per-tenant runtime state the driver tracks outside the simulation.
struct TenantRun {
    algo: Box<dyn Algorithm>,
    slot: usize,
    init_channels: u32,
    admitted: bool,
    finished_at: Option<SimTime>,
    /// Absolute time (seconds) of the next tuning timeout.
    next_timeout: f64,
    timeout: f64,
    peak_channels: u32,
    timeline: Vec<TimelinePoint>,
    /// In fleet mode the policy owns the real host CPU; the tenant's
    /// governor actuates this per-tenant shadow setting instead, so even
    /// baselines with built-in OS governors cannot fight the policy.
    shadow_cpu: CpuState,
}

/// Install the policy's per-session channel budget on one tenant's
/// engine: future `set_num_channels` calls clamp to it (no churn), and a
/// count already above the new budget shrinks once now.
fn apply_cap(sim: &mut Simulation, slot: usize, cap: u32) {
    let engine = &mut sim.slot_mut(slot).engine;
    engine.set_channel_cap(Some(cap));
    if engine.num_channels() > cap {
        engine.update_weights();
        engine.set_num_channels(cap);
    }
}

/// Run a multi-tenant world to completion (or the time cap).
pub fn run_fleet(cfg: &FleetConfig) -> FleetOutcome {
    assert!(!cfg.tenants.is_empty(), "a fleet needs at least one tenant");

    let mut policy: Option<Box<dyn FleetPolicy>> =
        cfg.policy.map(|kind| kind.build(&cfg.params));

    // In fleet mode the policy owns the host CPU: tenant governors are
    // replaced by the null governor so they cannot fight over the package.
    let mut params = cfg.params;
    if policy.is_some() {
        params.governor = GovernorKind::None;
    }

    // Initialize every tenant's algorithm and engine up front (Alg. 1 runs
    // at submission time); engines stay parked until admission.
    let mut tenants: Vec<TenantRun> = Vec::with_capacity(cfg.tenants.len());
    let mut engines: Vec<TransferEngine> = Vec::with_capacity(cfg.tenants.len());
    let mut first_cpu: Option<CpuState> = None;
    for spec in &cfg.tenants {
        let mut algo = spec.algorithm.build(params);
        let plan = algo.init(&cfg.testbed, &spec.dataset);
        let mut engine = TransferEngine::with_knee(
            &plan.partitions,
            cfg.testbed.link.avg_win,
            cfg.testbed.link.knee_streams(),
        );
        if plan.handshake_rtts > 0.0 {
            for i in 0..plan.partitions.len() {
                engine.set_handshake_rtts(i, plan.handshake_rtts);
            }
        }
        engine.update_weights();
        if first_cpu.is_none() {
            first_cpu = Some(plan.client_cpu.clone());
        }
        // Floored so a degenerate timeout cannot stall the catch-up loop.
        let timeout = algo.timeout().as_secs().max(1e-3);
        tenants.push(TenantRun {
            algo,
            slot: 0, // assigned below
            init_channels: plan.num_channels,
            admitted: false,
            finished_at: None,
            next_timeout: spec.arrive_at.as_secs() + timeout,
            timeout,
            peak_channels: 0,
            timeline: Vec::new(),
            shadow_cpu: plan.client_cpu,
        });
        engines.push(engine);
    }

    // The host CPU starts where the policy (or, without one, the first
    // tenant's Algorithm-1 plan) says.
    let fleet_managed = policy.is_some();
    let client = match &policy {
        Some(p) => p.initial_cpu(&cfg.testbed.client_cpu),
        None => first_cpu.expect("at least one tenant"),
    };
    let mut sim = Simulation::empty(
        &cfg.testbed,
        client,
        cfg.tick,
        cfg.seed,
        cfg.bandwidth_events.clone(),
    );
    sim.host.server_autoscale = cfg.server_scaling;
    for (t, engine) in tenants.iter_mut().zip(engines) {
        t.slot = sim.add_slot(engine);
    }

    // Arbitration cadence, floored at one tick so a degenerate config
    // cannot stall the catch-up loop below.
    let fleet_step = cfg.fleet_interval.as_secs().max(cfg.tick.as_secs()).max(1e-3);
    let mut next_fleet = fleet_step;
    let mut channel_cap: Option<u32> = None;

    while !sim.is_done() && sim.now.as_secs() < cfg.max_sim_time.as_secs() {
        // Admissions due now (t=0 tenants are admitted before the first
        // tick; channels open cold, exactly like a fresh session).
        for (t, spec) in tenants.iter_mut().zip(&cfg.tenants) {
            if !t.admitted && spec.arrive_at.as_secs() <= sim.now.as_secs() + 1e-9 {
                t.admitted = true;
                sim.activate_slot(t.slot);
                let engine = &mut sim.slot_mut(t.slot).engine;
                engine.set_channel_cap(channel_cap);
                engine.update_weights();
                engine.set_num_channels(t.init_channels);
                t.peak_channels = engine.num_channels();
            }
        }

        // Channel counts only move at the driver-level events that bound
        // this segment (tuning, arbitration, admission) or drop to zero on
        // completion, so sampling the peak once per segment equals the
        // old per-tick max.
        for t in tenants.iter_mut() {
            if t.admitted && t.finished_at.is_none() {
                t.peak_channels =
                    t.peak_channels.max(sim.slot(t.slot).engine.num_channels());
            }
        }

        // Event horizon: the earliest instant any driver-level event can
        // fire. Between now and then every tick is pure stepping, so run
        // a tight inner loop that skips the per-tick deadline re-checks
        // the old driver made. Completions end a segment early (the
        // departure scan must run on exactly the tick a tenant finishes,
        // as it would per-tick). The break comparison is the identical
        // `now + 1e-9 >= deadline` the per-tick scans below make, so no
        // event fires earlier or later than it did pre-horizon.
        let mut horizon = cfg.max_sim_time.as_secs();
        for (t, spec) in tenants.iter().zip(&cfg.tenants) {
            if !t.admitted {
                horizon = horizon.min(spec.arrive_at.as_secs());
            } else if t.finished_at.is_none() {
                horizon = horizon.min(t.next_timeout);
            }
        }
        if policy.is_some() {
            horizon = horizon.min(next_fleet);
        }
        loop {
            let stats =
                if cfg.reference_stepper { sim.step_reference() } else { sim.step() };
            if stats.session_completed
                || sim.now.as_secs() + 1e-9 >= horizon
                || sim.now.as_secs() >= cfg.max_sim_time.as_secs()
            {
                break;
            }
        }

        // Per-tenant tuning timeouts. A tick that overshoots several
        // timeouts drains once and then advances `next_timeout` past the
        // clock, so long ticks cannot skew the tuning cadence.
        for t in tenants.iter_mut() {
            if !t.admitted || t.finished_at.is_some() {
                continue;
            }
            if sim.now.as_secs() + 1e-9 >= t.next_timeout {
                let tel = sim.drain_telemetry_for(t.slot);
                if cfg.record_timeline {
                    t.timeline.push(TimelinePoint {
                        t_secs: tel.now.as_secs(),
                        fsm: t.algo.fsm_label(),
                        throughput: tel.avg_throughput,
                        channels: tel.num_channels,
                        active_cores: sim.host.client.active_cores(),
                        freq: sim.host.client.freq(),
                        cpu_load: tel.cpu_load,
                        power_w: tel.avg_power.as_watts(),
                    });
                }
                if fleet_managed {
                    // The policy owns the real host CPU: hand the tenant's
                    // governor a shadow setting it can harmlessly actuate.
                    let ctx = &mut TuneCtx {
                        engine: &mut sim.slot_mut(t.slot).engine,
                        client: &mut t.shadow_cpu,
                    };
                    t.algo.on_timeout(&tel, ctx);
                } else {
                    t.algo.on_timeout(&tel, &mut sim.tune_ctx(t.slot));
                }
                t.next_timeout += t.timeout;
                while sim.now.as_secs() + 1e-9 >= t.next_timeout {
                    t.next_timeout += t.timeout;
                }
            }
        }

        // Host-level arbitration at the fleet cadence.
        if let Some(p) = policy.as_mut() {
            if sim.now.as_secs() + 1e-9 >= next_fleet {
                let active = sim.active_sessions();
                let view = sim.host.drain_fleet_interval(sim.now, active);
                let directive = p.arbitrate(&view, &mut sim.host.client);
                channel_cap = directive.per_session_channel_cap;
                if let Some(cap) = channel_cap {
                    for t in tenants.iter() {
                        if t.admitted && t.finished_at.is_none() {
                            apply_cap(&mut sim, t.slot, cap);
                        }
                    }
                }
                next_fleet += fleet_step;
                while sim.now.as_secs() + 1e-9 >= next_fleet {
                    next_fleet += fleet_step;
                }
            }
        }

        // Departures: a finished tenant releases its share of the host.
        for t in tenants.iter_mut() {
            if t.admitted
                && t.finished_at.is_none()
                && sim.slot(t.slot).engine.is_done()
            {
                t.finished_at = Some(sim.now);
                sim.deactivate_slot(t.slot);
            }
        }
    }

    let completed = sim.is_done();
    let duration = sim.now.since(SimTime::ZERO);

    let mut outcomes = Vec::with_capacity(tenants.len());
    let mut moved_total = Bytes::ZERO;
    for (t, spec) in tenants.into_iter().zip(&cfg.tenants) {
        let slot = sim.slot(t.slot);
        let moved = slot.engine.total().saturating_sub(slot.engine.remaining());
        moved_total += moved;
        let end = t.finished_at.unwrap_or(sim.now);
        let residency = if t.admitted {
            end.since(slot.arrived_at())
        } else {
            SimDuration::ZERO
        };
        outcomes.push(TenantOutcome {
            name: spec.name.clone(),
            algorithm: t.algo.name().to_string(),
            completed: t.finished_at.is_some(),
            arrived_at: spec.arrive_at,
            finished_at: t.finished_at,
            moved,
            avg_throughput: Rate::average(moved, residency),
            residency,
            attributed_energy: slot.attributed_energy(),
            attributed_package_energy: slot.attributed_package_energy(),
            peak_channels: t.peak_channels,
            timeline: t.timeline,
        });
    }

    FleetOutcome {
        policy: match &policy {
            Some(p) => p.name().to_string(),
            None => "none".to_string(),
        },
        tenants: outcomes,
        completed,
        duration,
        moved: moved_total,
        client_energy: sim.client_energy(),
        client_package_energy: sim.host.client_rapl.total(),
        server_energy: sim.server_energy(),
        final_active_cores: sim.host.client.active_cores(),
        final_freq: sim.host.client.freq(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    fn four_tenant_cfg(policy: FleetPolicyKind, seed: u64) -> FleetConfig {
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(policy)).with_seed(seed);
        for i in 0..4u64 {
            cfg.tenants.push(
                TenantSpec::new(
                    format!("tenant-{i}"),
                    standard::medium_dataset(seed + i),
                    AlgorithmKind::MaxThroughput,
                )
                .arriving_at(SimTime::from_secs(20.0 * i as f64)),
            );
        }
        cfg
    }

    #[test]
    fn fleet_run_completes_and_accounts_every_tenant() {
        let out = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 7));
        assert!(out.completed, "all tenants must finish");
        assert_eq!(out.tenants.len(), 4);
        for t in &out.tenants {
            assert!(t.completed, "{} unfinished", t.name);
            assert!(t.moved.as_gb() > 1.0, "{} moved {}", t.name, t.moved);
            assert!(t.attributed_energy.as_joules() > 0.0);
            assert!(t.avg_throughput.as_mbps() > 10.0);
            assert!(t.finished_at.unwrap() > t.arrived_at);
        }
        // Attribution is conservative: tenant shares sum to the host bill.
        let attributed: f64 =
            out.tenants.iter().map(|t| t.attributed_energy.as_joules()).sum();
        let host = out.client_energy.as_joules();
        assert!(
            (attributed - host).abs() < 1e-6 * host,
            "attributed {attributed} vs host {host}"
        );
    }

    #[test]
    fn fleet_deterministic_given_seed() {
        let a = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 123));
        let b = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 123));
        assert_eq!(a.duration.as_secs(), b.duration.as_secs());
        assert_eq!(a.client_energy.as_joules(), b.client_energy.as_joules());
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                x.attributed_energy.as_joules(),
                y.attributed_energy.as_joules(),
                "{} energy must be reproducible",
                x.name
            );
            assert_eq!(x.finished_at.unwrap().as_secs(), y.finished_at.unwrap().as_secs());
        }
        // And a different seed perturbs the background traffic.
        let c = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 124));
        assert_ne!(a.client_energy.as_joules(), c.client_energy.as_joules());
    }

    #[test]
    fn min_energy_fleet_beats_fair_share_on_energy() {
        // The whole point of the fleet policy: tracking aggregate load
        // burns less host energy than pinning the performance governor.
        let eco = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 9));
        let perf = run_fleet(&four_tenant_cfg(FleetPolicyKind::FairShare, 9));
        assert!(eco.completed && perf.completed);
        assert!(
            eco.client_energy.as_joules() < 0.9 * perf.client_energy.as_joules(),
            "fleet scaling must save energy: {} vs {}",
            eco.client_energy,
            perf.client_energy
        );
    }

    #[test]
    fn baseline_tenants_cannot_fight_the_policy() {
        // curl's built-in ondemand governor actuates only its shadow CPU;
        // the policy-owned host setting must stay where FairShare pinned
        // it (performance: max cores, max frequency) for the whole run.
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(4);
        for i in 0..2u64 {
            cfg.tenants.push(TenantSpec::new(
                format!("t{i}"),
                standard::medium_dataset(4 + i),
                AlgorithmKind::Curl,
            ));
        }
        let out = run_fleet(&cfg);
        assert!(out.completed);
        let spec = testbeds::cloudlab().client_cpu;
        assert_eq!(out.final_active_cores, spec.num_cores);
        assert!(
            (out.final_freq.as_ghz() - spec.max_freq().as_ghz()).abs() < 1e-9,
            "host frequency moved to {} despite the policy owning it",
            out.final_freq
        );
    }

    #[test]
    fn late_arrivals_wait_for_admission() {
        let cfg = four_tenant_cfg(FleetPolicyKind::FairShare, 5);
        let out = run_fleet(&cfg);
        for (i, t) in out.tenants.iter().enumerate() {
            assert!((t.arrived_at.as_secs() - 20.0 * i as f64).abs() < 1e-9);
            assert!(
                t.finished_at.unwrap().as_secs() >= t.arrived_at.as_secs(),
                "{} finished before arriving",
                t.name
            );
        }
    }

    #[test]
    fn per_session_cap_bounds_channels() {
        // 4 tenants under the default 48-channel budget: while all four
        // are resident, nobody may exceed 48/4 = 12 channels once the
        // first arbitration has run (departures later raise the cap).
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(11);
        for i in 0..4u64 {
            cfg.tenants.push(TenantSpec::new(
                format!("tenant-{i}"),
                standard::medium_dataset(11 + i),
                AlgorithmKind::MaxThroughput,
            ));
        }
        cfg.record_timeline = true;
        let out = run_fleet(&cfg);
        let first_exit = out
            .tenants
            .iter()
            .map(|t| t.finished_at.unwrap().as_secs())
            .fold(f64::MAX, f64::min);
        for t in &out.tenants {
            for p in &t.timeline {
                // Points record the state *before* that timeout's tuning
                // step; the cap from the first arbitration (t=3 s) is
                // visible from the second point on.
                if p.t_secs >= 6.0 - 1e-9 && p.t_secs < first_exit {
                    assert!(
                        p.channels <= 12,
                        "{} ran {} channels at t={} under a fair-share cap",
                        t.name,
                        p.channels,
                        p.t_secs
                    );
                }
            }
        }
    }
}
