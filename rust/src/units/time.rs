//! Simulation clock types.

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Sub};

/// A point on the simulation clock, in seconds since session start.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimTime(f64);

impl SimTime {
    /// The simulation epoch (t = 0).
    pub const ZERO: SimTime = SimTime(0.0);

    /// Construct from seconds since the epoch.
    pub fn from_secs(s: f64) -> Self {
        SimTime(s)
    }

    /// Seconds since the simulation epoch.
    pub fn as_secs(self) -> f64 {
        self.0
    }

    /// Duration since an earlier instant (saturates at zero).
    pub fn since(self, earlier: SimTime) -> SimDuration {
        SimDuration::from_secs((self.0 - earlier.0).max(0.0))
    }
}

impl Add<SimDuration> for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimDuration) -> SimTime {
        SimTime(self.0 + rhs.as_secs())
    }
}

impl AddAssign<SimDuration> for SimTime {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.as_secs();
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "t={:.2}s", self.0)
    }
}

/// A span of simulation time, in seconds. Never negative.
#[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
pub struct SimDuration(f64);

impl SimDuration {
    /// Zero duration.
    pub const ZERO: SimDuration = SimDuration(0.0);

    /// Construct from seconds.
    pub fn from_secs(s: f64) -> Self {
        SimDuration(if s > 0.0 { s } else { 0.0 })
    }

    /// Construct from milliseconds.
    pub fn from_millis(ms: f64) -> Self {
        SimDuration::from_secs(ms / 1e3)
    }

    /// Value in seconds.
    pub fn as_secs(self) -> f64 {
        self.0
    }

    /// Value in milliseconds.
    pub fn as_millis(self) -> f64 {
        self.0 * 1e3
    }

    /// True for a zero-length duration.
    pub fn is_zero(self) -> bool {
        self.0 <= 0.0
    }

    /// The shorter of two durations.
    pub fn min(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0.min(other.0))
    }

    /// The longer of two durations.
    pub fn max(self, other: SimDuration) -> SimDuration {
        SimDuration(self.0.max(other.0))
    }
}

impl Add for SimDuration {
    type Output = SimDuration;
    fn add(self, rhs: SimDuration) -> SimDuration {
        SimDuration(self.0 + rhs.0)
    }
}

impl AddAssign for SimDuration {
    fn add_assign(&mut self, rhs: SimDuration) {
        self.0 += rhs.0;
    }
}

impl Sub for SimDuration {
    type Output = SimDuration;
    fn sub(self, rhs: SimDuration) -> SimDuration {
        SimDuration::from_secs(self.0 - rhs.0)
    }
}

impl Mul<f64> for SimDuration {
    type Output = SimDuration;
    fn mul(self, rhs: f64) -> SimDuration {
        SimDuration::from_secs(self.0 * rhs)
    }
}

impl Div<f64> for SimDuration {
    type Output = SimDuration;
    fn div(self, rhs: f64) -> SimDuration {
        SimDuration::from_secs(self.0 / rhs)
    }
}

impl Div for SimDuration {
    type Output = f64;
    fn div(self, rhs: SimDuration) -> f64 {
        if rhs.0 == 0.0 {
            0.0
        } else {
            self.0 / rhs.0
        }
    }
}

impl Sum for SimDuration {
    fn sum<I: Iterator<Item = SimDuration>>(iter: I) -> SimDuration {
        iter.fold(SimDuration::ZERO, |a, b| a + b)
    }
}

impl fmt::Display for SimDuration {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 >= 1.0 {
            write!(f, "{:.2}s", self.0)
        } else {
            write!(f, "{:.0}ms", self.as_millis())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_advances_by_duration() {
        let mut t = SimTime::ZERO;
        t += SimDuration::from_millis(100.0);
        assert!((t.as_secs() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn since_saturates() {
        let a = SimTime::from_secs(1.0);
        let b = SimTime::from_secs(3.0);
        assert_eq!(b.since(a).as_secs(), 2.0);
        assert_eq!(a.since(b), SimDuration::ZERO);
    }

    #[test]
    fn duration_never_negative() {
        assert_eq!(SimDuration::from_secs(-1.0), SimDuration::ZERO);
        assert_eq!(SimDuration::from_secs(1.0) - SimDuration::from_secs(2.0), SimDuration::ZERO);
    }

    #[test]
    fn ratio() {
        let a = SimDuration::from_secs(1.0);
        let b = SimDuration::from_secs(4.0);
        assert_eq!(a / b, 0.25);
    }
}
