//! Figure 4 — effect of frequency and core scaling on the client's
//! energy consumption (the load-control ablation).
//!
//! Six bars per testbed, mixed dataset, client energy only:
//! Alan-ME, ME w/o scaling, ME, Alan-MT, EEMT w/o scaling, EEMT.
//!
//! Paper shapes (§V-C): on Chameleon, ME w/o scaling already saves ~42 %
//! vs Alan-ME, and load control adds ~19 pp more (total ~53 %); EEMT w/o
//! scaling saves ~30 % vs Alan-MT, +17 pp with scaling (total ~43 %).
//! On DIDCLab the no-scaling gains are small (~9 %/~8 %) but scaling
//! lifts them to ~22 %/~23 %.

use super::common::{fmt_energy_kj, fmt_tput, run_cells, Cell};
use crate::config::experiment::TunerParams;
use crate::coordinator::AlgorithmKind;
use crate::metrics::Table;
use crate::sim::session::SessionOutcome;
use std::path::Path;

/// Testbeds of the Figure 4 ablation, paper order.
pub const TESTBEDS: [&str; 3] = ["chameleon", "cloudlab", "didclab"];

/// The six bars of each Figure 4 panel.
pub fn variants() -> Vec<(&'static str, AlgorithmKind, TunerParams)> {
    let base = TunerParams::default();
    vec![
        ("Alan-ME", AlgorithmKind::AlanMinEnergy, base),
        ("ME w/o scaling", AlgorithmKind::MinEnergy, base.without_scaling()),
        ("ME", AlgorithmKind::MinEnergy, base),
        ("Alan-MT", AlgorithmKind::AlanMaxThroughput, base),
        ("EEMT w/o scaling", AlgorithmKind::MaxThroughput, base.without_scaling()),
        ("EEMT", AlgorithmKind::MaxThroughput, base),
    ]
}

/// All outcomes of the Figure 4 scaling ablation.
pub struct Fig4Results {
    /// (testbed, variant, outcome)
    pub outcomes: Vec<(String, String, SessionOutcome)>,
    /// Rendered tables.
    pub tables: Vec<Table>,
}

/// Run the Figure 4 ablation at `seed`.
pub fn run(seed: u64) -> Fig4Results {
    let vars = variants();
    let mut cells = Vec::new();
    for tb in TESTBEDS {
        for (_, kind, params) in &vars {
            cells.push(Cell::new(tb, "mixed", *kind).with_params(*params).with_seed(seed));
        }
    }
    let outs = run_cells(&cells);

    let mut outcomes = Vec::new();
    let mut tables = Vec::new();
    let mut idx = 0;
    for tb in TESTBEDS {
        let mut t = Table::new(
            format!("Figure 4 — client energy on {tb} (mixed dataset)"),
            &["variant", "client energy", "throughput", "final cores", "final freq"],
        );
        for (name, _, _) in &vars {
            let out = &outs[idx];
            idx += 1;
            t.push_row(vec![
                name.to_string(),
                fmt_energy_kj(out.client_energy.as_joules()),
                fmt_tput(out),
                out.final_active_cores.to_string(),
                format!("{}", out.final_freq),
            ]);
            outcomes.push((tb.to_string(), name.to_string(), out.clone()));
        }
        tables.push(t);
    }
    Fig4Results { outcomes, tables }
}

impl Fig4Results {
    /// Look one cell up by testbed and variant.
    pub fn outcome(&self, tb: &str, variant: &str) -> &SessionOutcome {
        &self
            .outcomes
            .iter()
            .find(|(t, v, _)| t == tb && v == variant)
            .expect("cell present")
            .2
    }

    /// Energy reduction of `variant` relative to `reference` on `tb`.
    pub fn reduction(&self, tb: &str, variant: &str, reference: &str) -> f64 {
        let v = self.outcome(tb, variant).client_energy.as_joules();
        let r = self.outcome(tb, reference).client_energy.as_joules();
        1.0 - v / r
    }

    /// Print the headline savings.
    pub fn print_headlines(&self) {
        for tb in TESTBEDS {
            println!("Fig4 on {tb} (vs Alan et al., client energy):");
            println!(
                "  ME   w/o scaling {:+.0}%, with scaling {:+.0}%  (paper Chameleon: -42%/-53%)",
                -self.reduction(tb, "ME w/o scaling", "Alan-ME") * 100.0,
                -self.reduction(tb, "ME", "Alan-ME") * 100.0,
            );
            println!(
                "  EEMT w/o scaling {:+.0}%, with scaling {:+.0}%  (paper Chameleon: -30%/-43%)",
                -self.reduction(tb, "EEMT w/o scaling", "Alan-MT") * 100.0,
                -self.reduction(tb, "EEMT", "Alan-MT") * 100.0,
            );
        }
    }

    /// Write the per-panel CSV files into `dir`.
    pub fn save_csvs(&self, dir: impl AsRef<Path>) -> anyhow::Result<()> {
        let dir = dir.as_ref();
        for (t, tb) in self.tables.iter().zip(TESTBEDS) {
            t.save_csv(dir.join(format!("fig4_{tb}.csv")))?;
        }
        Ok(())
    }
}
