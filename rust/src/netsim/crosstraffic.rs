//! Seeded background cross-traffic generators.
//!
//! The OU process in [`super::BackgroundTraffic`] models *diffuse* load:
//! many small flows whose aggregate drifts around a mean. Real contended
//! paths additionally carry structured competitors — a steady UDP floor
//! (monitoring, VoIP, telemetry) and bursty TCP flows that arrive, pump a
//! bounded number of bytes, and leave. This module reproduces the classic
//! mgen experiment shape (an mgen config scripts exactly these two
//! generators): a constant-rate UDP component plus TCP bursts with a mean
//! size, a fixed duration and Poisson inter-burst gaps.
//!
//! [`CrossTraffic`] owns its own RNG stream (derived from the seed at
//! construction), so a generator's fraction trajectory is a pure function
//! of `(config, seed)` — bit-identical across runs regardless of what the
//! rest of the simulation draws. The determinism tests in
//! `rust/tests/fairness_convergence.rs` pin this.
//!
//! A link carrying an active generator is **never frozen**
//! ([`crate::netsim::Link::bg_frozen`] returns `false`), so the
//! warm-epoch batched stepper always falls back to the per-tick path and
//! can never replay a stale rate across a burst boundary.

use crate::rng::{self, Distribution, Exponential, Xoshiro256};
use crate::units::{Rate, SimTime};

/// Hard ceiling on the combined (OU + cross-traffic) fraction of the
/// bottleneck: however bursty the competitors, the transfer keeps a
/// sliver of the pipe (TCP never fully starves).
pub const MAX_CROSS_FRACTION: f64 = 0.98;

/// Parameters of the seeded cross-traffic generators: a steady UDP floor
/// plus mgen-style bursty TCP flows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrossTrafficConfig {
    /// Steady UDP floor as a fraction of link capacity, `[0, 1)`.
    pub udp_fraction: f64,
    /// Mean TCP burst arrivals per second (Poisson). `0` disables the
    /// bursty component.
    pub tcp_rate_per_sec: f64,
    /// Mean bytes per TCP burst (sizes are exponentially distributed).
    pub tcp_burst_bytes: f64,
    /// Duration of each burst, seconds: a burst of `S` bytes occupies
    /// `S / duration` bytes/s of the bottleneck while it lasts.
    pub tcp_burst_secs: f64,
}

impl CrossTrafficConfig {
    /// A config with only the steady UDP floor.
    pub fn udp_floor(fraction: f64) -> Self {
        CrossTrafficConfig {
            udp_fraction: fraction,
            tcp_rate_per_sec: 0.0,
            tcp_burst_bytes: 0.0,
            tcp_burst_secs: 1.0,
        }
    }

    /// True when the config generates any load at all — an inactive
    /// config must not be attached to a link (it would unfreeze warm
    /// batching for nothing).
    pub fn is_active(&self) -> bool {
        self.udp_fraction > 0.0 || self.tcp_rate_per_sec > 0.0
    }

    /// Validate the parameter ranges; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if !(0.0..1.0).contains(&self.udp_fraction) {
            return Err(format!(
                "udp fraction {} must be in [0, 1)",
                self.udp_fraction
            ));
        }
        if self.tcp_rate_per_sec < 0.0 || !self.tcp_rate_per_sec.is_finite() {
            return Err(format!(
                "tcp burst rate {} must be finite and >= 0",
                self.tcp_rate_per_sec
            ));
        }
        if self.tcp_rate_per_sec > 0.0 {
            if !(self.tcp_burst_bytes > 0.0 && self.tcp_burst_bytes.is_finite()) {
                return Err(format!(
                    "tcp burst size {} must be finite and > 0",
                    self.tcp_burst_bytes
                ));
            }
            if !(self.tcp_burst_secs > 0.0 && self.tcp_burst_secs.is_finite()) {
                return Err(format!(
                    "tcp burst duration {} must be finite and > 0",
                    self.tcp_burst_secs
                ));
            }
        }
        Ok(())
    }

    /// Parse the CLI spec `"udp:FRAC;tcp:RATE:SIZE:DUR"` (either component
    /// may be given alone; `"off"` yields `None`). `RATE` is bursts per
    /// second, `SIZE` mean bytes per burst, `DUR` the burst duration in
    /// seconds.
    ///
    /// # Examples
    ///
    /// ```
    /// use greendt::netsim::CrossTrafficConfig;
    ///
    /// assert_eq!(CrossTrafficConfig::parse("off").unwrap(), None);
    /// let cfg = CrossTrafficConfig::parse("udp:0.1;tcp:0.05:4000000:2")
    ///     .unwrap()
    ///     .unwrap();
    /// assert_eq!(cfg.udp_fraction, 0.1);
    /// assert_eq!(cfg.tcp_rate_per_sec, 0.05);
    /// ```
    pub fn parse(spec: &str) -> Result<Option<Self>, String> {
        let spec = spec.trim();
        if spec.eq_ignore_ascii_case("off") {
            return Ok(None);
        }
        if spec.is_empty() {
            return Err("empty cross-traffic spec (use 'off' to disable)".into());
        }
        let mut cfg = CrossTrafficConfig {
            udp_fraction: 0.0,
            tcp_rate_per_sec: 0.0,
            tcp_burst_bytes: 0.0,
            tcp_burst_secs: 1.0,
        };
        let num = |s: &str, what: &str| -> Result<f64, String> {
            s.parse::<f64>()
                .map_err(|_| format!("bad {what} '{s}' in cross-traffic spec"))
        };
        for part in spec.split(';').map(str::trim).filter(|p| !p.is_empty()) {
            if let Some(frac) = part.strip_prefix("udp:") {
                cfg.udp_fraction = num(frac, "udp fraction")?;
            } else if let Some(rest) = part.strip_prefix("tcp:") {
                let fields: Vec<&str> = rest.split(':').collect();
                if fields.len() != 3 {
                    return Err(format!(
                        "tcp component '{part}' must be tcp:RATE:SIZE:DUR"
                    ));
                }
                cfg.tcp_rate_per_sec = num(fields[0], "tcp burst rate")?;
                cfg.tcp_burst_bytes = num(fields[1], "tcp burst size")?;
                cfg.tcp_burst_secs = num(fields[2], "tcp burst duration")?;
            } else {
                return Err(format!(
                    "unknown cross-traffic component '{part}' (expected udp:… or tcp:…)"
                ));
            }
        }
        cfg.validate()?;
        if !cfg.is_active() {
            return Err("cross-traffic spec generates no load (use 'off' to disable)".into());
        }
        Ok(Some(cfg))
    }
}

/// One in-flight TCP burst: it occupies `bytes_per_sec` of the bottleneck
/// until `ends_at`.
#[derive(Debug, Clone, Copy)]
struct Burst {
    ends_at: f64,
    bytes_per_sec: f64,
}

/// The live generator state composed onto a [`crate::netsim::Link`]: a
/// constant UDP floor plus the currently active TCP bursts. Owns its RNG,
/// so the trajectory depends only on `(config, seed)`.
#[derive(Debug, Clone)]
pub struct CrossTraffic {
    cfg: CrossTrafficConfig,
    rng: Xoshiro256,
    /// When the next burst begins (absolute sim time, seconds).
    next_burst_at: f64,
    /// Bursts currently occupying the link.
    bursts: Vec<Burst>,
    /// Cached sum of active burst rates, bytes/s.
    load_bytes_per_sec: f64,
}

impl CrossTraffic {
    /// Build a generator from a validated config. The RNG stream is
    /// derived from `seed` with a fixed label, so the generator's draws
    /// never interleave with (or perturb) any other stream in the run.
    pub fn new(cfg: CrossTrafficConfig, seed: u64) -> Self {
        cfg.validate().unwrap_or_else(|e| panic!("invalid cross-traffic config: {e}"));
        let mut rng = rng::stream(seed, "cross-traffic");
        let next_burst_at = if cfg.tcp_rate_per_sec > 0.0 {
            Exponential::new(cfg.tcp_rate_per_sec).sample(&mut rng)
        } else {
            f64::INFINITY
        };
        CrossTraffic {
            cfg,
            rng,
            next_burst_at,
            bursts: Vec::with_capacity(32),
            load_bytes_per_sec: 0.0,
        }
    }

    /// The configuration this generator runs.
    pub fn config(&self) -> &CrossTrafficConfig {
        &self.cfg
    }

    /// Advance the generators to `now`: expire finished bursts, start
    /// every burst whose Poisson-scheduled instant has arrived (bursts
    /// overlap freely), and refresh the cached load.
    pub fn tick(&mut self, now: SimTime) {
        let t = now.as_secs();
        self.bursts.retain(|b| b.ends_at > t);
        if self.cfg.tcp_rate_per_sec > 0.0 {
            let gap = Exponential::new(self.cfg.tcp_rate_per_sec);
            let size = Exponential::new(1.0 / self.cfg.tcp_burst_bytes);
            while self.next_burst_at <= t {
                let bytes = size.sample(&mut self.rng);
                self.bursts.push(Burst {
                    ends_at: self.next_burst_at + self.cfg.tcp_burst_secs,
                    bytes_per_sec: bytes / self.cfg.tcp_burst_secs,
                });
                self.next_burst_at += gap.sample(&mut self.rng);
            }
        }
        self.load_bytes_per_sec = self.bursts.iter().map(|b| b.bytes_per_sec).sum();
    }

    /// Current burst load on the link, bytes/s (the UDP floor is a
    /// capacity fraction and not included here).
    pub fn load_bytes_per_sec(&self) -> f64 {
        self.load_bytes_per_sec
    }

    /// Fraction of `capacity` the generators currently occupy: the UDP
    /// floor plus the active bursts, capped at [`MAX_CROSS_FRACTION`].
    pub fn fraction(&self, capacity: Rate) -> f64 {
        let cap = capacity.as_bytes_per_sec().max(1.0);
        (self.cfg.udp_fraction + self.load_bytes_per_sec / cap).min(MAX_CROSS_FRACTION)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::units::SimDuration;

    fn cfg() -> CrossTrafficConfig {
        CrossTrafficConfig {
            udp_fraction: 0.1,
            tcp_rate_per_sec: 0.2,
            tcp_burst_bytes: 25e6,
            tcp_burst_secs: 2.0,
        }
    }

    fn run(ct: &mut CrossTraffic, ticks: usize, capacity: Rate) -> Vec<f64> {
        let dt = SimDuration::from_millis(100.0);
        let mut t = SimTime::ZERO;
        let mut out = Vec::with_capacity(ticks);
        for _ in 0..ticks {
            ct.tick(t);
            out.push(ct.fraction(capacity));
            t += dt;
        }
        out
    }

    #[test]
    fn same_seed_is_bit_identical() {
        let capacity = Rate::from_gbps(1.0);
        let a = run(&mut CrossTraffic::new(cfg(), 7), 5000, capacity);
        let b = run(&mut CrossTraffic::new(cfg(), 7), 5000, capacity);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // A different seed produces a different trajectory.
        let c = run(&mut CrossTraffic::new(cfg(), 8), 5000, capacity);
        assert!(a.iter().zip(&c).any(|(x, y)| x != y));
    }

    #[test]
    fn mean_load_matches_configured_rates() {
        // Expected load: udp floor + λ·E[size] bytes/s of bursts. With
        // λ = 0.2/s and 25 MB mean bursts over a 1 Gbps (125 MB/s) link,
        // the burst component averages 5 MB/s = 4% of capacity.
        let capacity = Rate::from_gbps(1.0);
        let trace = run(&mut CrossTraffic::new(cfg(), 11), 200_000, capacity);
        let mean: f64 = trace.iter().sum::<f64>() / trace.len() as f64;
        let expected = 0.1 + 0.2 * 25e6 / capacity.as_bytes_per_sec();
        assert!(
            (mean - expected).abs() < 0.02,
            "mean fraction {mean} vs expected {expected}"
        );
        // Bursts actually fluctuate: the trace is not constant.
        assert!(trace.iter().any(|&f| f > expected * 1.2));
        assert!(trace.iter().any(|&f| (f - 0.1).abs() < 1e-12));
    }

    #[test]
    fn udp_only_floor_is_constant() {
        let capacity = Rate::from_gbps(1.0);
        let mut ct = CrossTraffic::new(CrossTrafficConfig::udp_floor(0.25), 3);
        for f in run(&mut ct, 1000, capacity) {
            assert_eq!(f, 0.25);
        }
    }

    #[test]
    fn fraction_is_capped() {
        // Absurd burst rates cannot starve the transfer entirely.
        let c = CrossTrafficConfig {
            udp_fraction: 0.5,
            tcp_rate_per_sec: 50.0,
            tcp_burst_bytes: 125e6,
            tcp_burst_secs: 5.0,
        };
        let capacity = Rate::from_gbps(1.0);
        let trace = run(&mut CrossTraffic::new(c, 5), 2000, capacity);
        assert!(trace.iter().all(|&f| f <= MAX_CROSS_FRACTION));
        assert!(trace.iter().any(|&f| f == MAX_CROSS_FRACTION));
    }

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        assert_eq!(CrossTrafficConfig::parse("off").unwrap(), None);
        assert_eq!(CrossTrafficConfig::parse("OFF").unwrap(), None);
        let c = CrossTrafficConfig::parse("udp:0.1;tcp:0.2:25000000:2")
            .unwrap()
            .unwrap();
        assert_eq!(c, cfg());
        let udp_only = CrossTrafficConfig::parse("udp:0.3").unwrap().unwrap();
        assert_eq!(udp_only.udp_fraction, 0.3);
        assert_eq!(udp_only.tcp_rate_per_sec, 0.0);
        let tcp_only = CrossTrafficConfig::parse("tcp:0.1:8000000:1.5").unwrap().unwrap();
        assert_eq!(tcp_only.udp_fraction, 0.0);
        assert_eq!(tcp_only.tcp_burst_secs, 1.5);

        for bad in [
            "",
            "udp:1.5",
            "udp:x",
            "tcp:0.1:100",
            "tcp:0.1:0:2",
            "tcp:0.1:100:-1",
            "wifi:0.1",
            "udp:0;tcp:0:1:1",
        ] {
            assert!(
                CrossTrafficConfig::parse(bad).is_err(),
                "'{bad}' should be rejected"
            );
        }
    }

    #[test]
    fn inactive_config_is_detectable() {
        assert!(!CrossTrafficConfig::udp_floor(0.0).is_active());
        assert!(CrossTrafficConfig::udp_floor(0.1).is_active());
        assert!(cfg().is_active());
    }
}
