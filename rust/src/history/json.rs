//! A minimal JSON value model and recursive-descent parser.
//!
//! The history store's JSONL format is written and read without external
//! crates (the offline build vendors nothing beyond `anyhow`/`log`), so
//! this module provides just enough JSON: parse one line into a [`Json`]
//! tree, and escape/render helpers for the writers in
//! [`super::record`]. Numbers are `f64` throughout — every quantity the
//! records carry is either a float or a small integer that `f64` holds
//! exactly — and Rust's shortest-round-trip `Display` for `f64` makes
//! write→parse reproduce the original bits.

use std::collections::BTreeMap;

/// One JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always parsed as `f64`).
    Num(f64),
    /// A string (escapes decoded).
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object; `BTreeMap` keeps key iteration deterministic.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Member `key` of an object (`None` for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The number in this value, if it is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The number as a `u32` (rounded; `None` when negative/out of range).
    pub fn as_u32(&self) -> Option<u32> {
        let x = self.as_f64()?;
        if x.is_finite() && (0.0..=u32::MAX as f64).contains(&x) {
            Some(x.round() as u32)
        } else {
            None
        }
    }

    /// The number as a `u64` (rounded; `None` when negative/out of range).
    pub fn as_u64(&self) -> Option<u64> {
        let x = self.as_f64()?;
        if x.is_finite() && x >= 0.0 && x <= 2f64.powi(53) {
            Some(x.round() as u64)
        } else {
            None
        }
    }

    /// The string in this value, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// The bool in this value, if it is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The array in this value, if it is one.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v.as_slice()),
            _ => None,
        }
    }
}

/// Parse one complete JSON document (trailing whitespace tolerated).
/// Returns `None` on any syntax error — the store counts such lines as
/// skipped rather than failing the whole load.
pub fn parse(text: &str) -> Option<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos == p.bytes.len() {
        Some(v)
    } else {
        None
    }
}

/// Escape a string for embedding in a JSON document (no surrounding
/// quotes).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an `f64` as a JSON number (`null` for non-finite values, which
/// JSON cannot represent).
pub fn num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Option<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Some(())
        } else {
            None
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Option<()> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Some(())
        } else {
            None
        }
    }

    fn value(&mut self) -> Option<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => self.string().map(Json::Str),
            b't' => self.eat_lit("true").map(|_| Json::Bool(true)),
            b'f' => self.eat_lit("false").map(|_| Json::Bool(false)),
            b'n' => self.eat_lit("null").map(|_| Json::Null),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Option<Json> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Some(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b'}' => {
                    self.pos += 1;
                    return Some(Json::Obj(map));
                }
                _ => return None,
            }
        }
    }

    fn array(&mut self) -> Option<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Some(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => {
                    self.pos += 1;
                }
                b']' => {
                    self.pos += 1;
                    return Some(Json::Arr(items));
                }
                _ => return None,
            }
        }
    }

    fn string(&mut self) -> Option<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.pos += 1;
                    return Some(out);
                }
                b'\\' => {
                    self.pos += 1;
                    match self.peek()? {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000C}'),
                        b'u' => {
                            self.pos += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: a second \uXXXX must follow.
                                self.eat_lit("\\u")?;
                                let lo = self.hex4()?;
                                if !(0xDC00..0xE000).contains(&lo) {
                                    return None;
                                }
                                let code =
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                char::from_u32(code)?
                            } else {
                                char::from_u32(hi)?
                            };
                            out.push(c);
                            continue; // hex4 already advanced past the digits
                        }
                        _ => return None,
                    }
                    self.pos += 1;
                }
                _ => {
                    // Consume one UTF-8 character (input is a &str, so the
                    // byte stream is valid UTF-8).
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).ok()?;
                    let c = rest.chars().next()?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Option<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.peek()?;
            let d = (b as char).to_digit(16)?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Some(v)
    }

    fn number(&mut self) -> Option<Json> {
        let start = self.pos;
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        if self.pos == start {
            return None;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).ok()?;
        s.parse::<f64>().ok().map(Json::Num)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null"), Some(Json::Null));
        assert_eq!(parse("true"), Some(Json::Bool(true)));
        assert_eq!(parse("false"), Some(Json::Bool(false)));
        assert_eq!(parse("3.25"), Some(Json::Num(3.25)));
        assert_eq!(parse("-1e9"), Some(Json::Num(-1e9)));
        assert_eq!(parse("\"hi\""), Some(Json::Str("hi".into())));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a":[1,2,{"b":"x"}],"c":{"d":null},"e":true}"#).unwrap();
        assert_eq!(v.get("e").and_then(Json::as_bool), Some(true));
        let arr = v.get("a").and_then(Json::as_arr).unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").and_then(Json::as_str), Some("x"));
        assert_eq!(v.get("c").unwrap().get("d"), Some(&Json::Null));
    }

    #[test]
    fn decodes_escapes_and_surrogates() {
        let v = parse(r#""a\"b\\c\ndA😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_none());
        assert!(parse("{").is_none());
        assert!(parse("{\"a\":}").is_none());
        assert!(parse("[1,2").is_none());
        assert!(parse("tru").is_none());
        assert!(parse("1.2.3").is_none());
        assert!(parse("{} trailing").is_none());
        assert!(parse(r#""\ud800x""#).is_none(), "lone high surrogate");
    }

    #[test]
    fn escape_round_trips_through_parse() {
        let s = "tab\tquote\"slash\\newline\nctrl\u{0001}π";
        let doc = format!("\"{}\"", escape(s));
        assert_eq!(parse(&doc).unwrap().as_str(), Some(s));
    }

    #[test]
    fn f64_display_round_trips_bits() {
        for x in [0.044, 1e9, 11.7e9, 1.0 / 3.0, f64::MIN_POSITIVE] {
            let back = parse(&num(x)).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        assert_eq!(num(f64::INFINITY), "null");
    }

    #[test]
    fn integer_accessors_guard_ranges() {
        assert_eq!(parse("7").unwrap().as_u32(), Some(7));
        assert_eq!(parse("-1").unwrap().as_u32(), None);
        assert_eq!(parse("4294967296").unwrap().as_u32(), None);
        assert_eq!(parse("4294967296").unwrap().as_u64(), Some(4_294_967_296));
    }
}
