//! Parameter sweeps and design-choice ablations (DESIGN.md §6).
//!
//! Not figures from the paper, but the experiments that *justify* its
//! design choices on this substrate:
//!
//! * [`concurrency_sweep`] — throughput and client energy as a function of
//!   a *fixed* channel count: exposes the concave throughput curve and the
//!   energy bathtub the FSM algorithms search (the reason runtime tuning
//!   beats any static choice);
//! * [`band_sensitivity`] — how the (α, β) feedback bands affect EEMT;
//! * [`timeout_sensitivity`] — tuning-interval length vs outcome;
//! * [`slow_start_ablation`] — Algorithm 2 on/off.

use super::common::{run_cell, run_cells, Cell};
use crate::config::experiment::TunerParams;
use crate::config::testbeds;
use crate::coordinator::AlgorithmKind;
use crate::dataset::standard;
use crate::metrics::Table;
use crate::units::SimDuration;

/// One point of the concurrency sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    /// Static channel count of the point.
    pub channels: u32,
    /// Whole-session throughput, Gbps.
    pub throughput_gbps: f64,
    /// Client energy, kJ.
    pub client_energy_kj: f64,
    /// Session duration, seconds.
    pub duration_s: f64,
}

/// Fixed-channel transfers (no tuning at all — performance governor,
/// static cc, parallelism pinned to 1 so the channel count is the only
/// concurrency knob) across a channel grid. This is the landscape the
/// paper's algorithms navigate online.
///
/// Each point runs through the regular session driver under the
/// [`crate::coordinator::no_tune::NoTune`] policy, so the codebase has a
/// single stepping loop.
pub fn concurrency_sweep(testbed_name: &str, dataset_name: &str, seed: u64) -> Vec<SweepPoint> {
    testbeds::by_name(testbed_name).expect("testbed");
    standard::by_name(dataset_name, seed).expect("dataset");
    let channel_grid = [1u32, 2, 3, 4, 6, 8, 12, 16, 24, 32, 48];
    // The 11 points are independent sessions (a slow-path single-channel
    // point simulates up to 36,000 s), so fan them out across the shared
    // worker pool instead of running them serially.
    let cells: Vec<Cell> = channel_grid
        .iter()
        .map(|&channels| {
            Cell::new(testbed_name, dataset_name, AlgorithmKind::NoTune(channels))
                .with_seed(seed)
                // Single-channel points on slow paths outlast the default cap.
                .with_max_sim_time(SimDuration::from_secs(36_000.0))
        })
        .collect();
    channel_grid
        .iter()
        .zip(run_cells(&cells))
        .map(|(&channels, out)| SweepPoint {
            channels,
            throughput_gbps: out.avg_throughput.as_gbps(),
            client_energy_kj: out.client_energy.as_joules() / 1e3,
            duration_s: out.duration.as_secs(),
        })
        .collect()
}

/// Render a sweep as a table.
pub fn sweep_table(testbed: &str, dataset: &str, points: &[SweepPoint]) -> Table {
    let mut t = Table::new(
        format!("concurrency sweep — {testbed} / {dataset} (static channels, OS governor)"),
        &["channels", "throughput", "client energy", "duration"],
    );
    for p in points {
        t.push_row(vec![
            p.channels.to_string(),
            format!("{:.2} Gbps", p.throughput_gbps),
            format!("{:.2} kJ", p.client_energy_kj),
            format!("{:.1} s", p.duration_s),
        ]);
    }
    t
}

/// (α, β) sensitivity of EEMT on Chameleon/mixed.
pub fn band_sensitivity(seed: u64) -> Table {
    let mut t = Table::new(
        "EEMT (alpha, beta) sensitivity — Chameleon / mixed",
        &["alpha", "beta", "throughput", "client energy", "peak channels"],
    );
    for (alpha, beta) in
        [(0.05, 0.02), (0.10, 0.05), (0.20, 0.10), (0.30, 0.20)]
    {
        let params = TunerParams { alpha, beta, ..TunerParams::default() };
        let out = run_cell(
            &Cell::new("chameleon", "mixed", AlgorithmKind::MaxThroughput)
                .with_params(params)
                .with_seed(seed),
        );
        t.push_row(vec![
            format!("{alpha}"),
            format!("{beta}"),
            format!("{}", out.avg_throughput),
            format!("{}", out.client_energy),
            out.peak_channels.to_string(),
        ]);
    }
    t
}

/// Tuning-interval sensitivity of ME on CloudLab/mixed.
pub fn timeout_sensitivity(seed: u64) -> Table {
    let mut t = Table::new(
        "ME timeout sensitivity — CloudLab / mixed",
        &["timeout", "throughput", "client energy"],
    );
    for secs in [1.0, 3.0, 5.0, 10.0] {
        let params =
            TunerParams { timeout: SimDuration::from_secs(secs), ..TunerParams::default() };
        let out = run_cell(
            &Cell::new("cloudlab", "mixed", AlgorithmKind::MinEnergy)
                .with_params(params)
                .with_seed(seed),
        );
        t.push_row(vec![
            format!("{secs} s"),
            format!("{}", out.avg_throughput),
            format!("{}", out.client_energy),
        ]);
    }
    t
}

/// Algorithm 2 ablation: slow-start correction on vs minimal.
pub fn slow_start_ablation(seed: u64) -> Table {
    let mut t = Table::new(
        "Slow Start (Alg. 2) ablation — EEMT, Chameleon / large",
        &["slow-start rounds", "throughput", "client energy", "peak channels"],
    );
    for rounds in [1u32, 2, 4] {
        let params = TunerParams { slow_start_rounds: rounds, ..TunerParams::default() };
        let out = run_cell(
            &Cell::new("chameleon", "large", AlgorithmKind::MaxThroughput)
                .with_params(params)
                .with_seed(seed),
        );
        t.push_row(vec![
            rounds.to_string(),
            format!("{}", out.avg_throughput),
            format!("{}", out.client_energy),
            out.peak_channels.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_shows_rise_then_saturation() {
        let pts = concurrency_sweep("cloudlab", "large", 42);
        assert_eq!(pts.len(), 11);
        // Throughput rises from 1 channel to the knee…
        assert!(pts[0].throughput_gbps < 0.4);
        let peak = pts.iter().map(|p| p.throughput_gbps).fold(0.0, f64::max);
        assert!(peak > 0.8, "peak {peak}");
        // …and the tail never collapses (graceful overload).
        assert!(pts.last().unwrap().throughput_gbps > 0.5 * peak);
    }

    #[test]
    fn energy_has_a_bathtub() {
        // Too few channels: long transfer at idle-ish power. The optimum
        // sits at moderate concurrency, clearly below both extremes' cost.
        let pts = concurrency_sweep("cloudlab", "large", 42);
        let first = pts.first().unwrap().client_energy_kj;
        let best = pts.iter().map(|p| p.client_energy_kj).fold(f64::MAX, f64::min);
        assert!(best < 0.6 * first, "single-channel {first} vs best {best}");
    }

    #[test]
    fn tables_render() {
        let pts = concurrency_sweep("didclab", "large", 1);
        let t = sweep_table("didclab", "large", &pts);
        assert_eq!(t.rows.len(), pts.len());
    }
}
