//! Power modeling and energy measurement.
//!
//! Substitutes the paper's measurement instruments:
//!
//! * **Intel RAPL** (used on Chameleon/CloudLab nodes) → [`PowerModel`] +
//!   [`RaplMeter`]: a package-level CMOS power model — static package
//!   power plus per-active-core idle and utilization-proportional dynamic
//!   power `util · κ · V(f)² · f`, with voltage affine in frequency across
//!   the P-state ladder, plus a DRAM term proportional to moved bytes.
//! * **Yokogawa WT210 wall meter** (DIDCLab client) → [`NodeMeter`]: RAPL
//!   plus a constant platform base (NIC, fans, VRs, disks idle).
//!
//! The cubic-ish growth of power in frequency (V scales with f, P with
//! V²·f) is the physics the paper's load-control module exploits: finishing
//! *slightly* slower at a much lower P-state usually wins on energy, unless
//! race-to-idle effects dominate — both regimes exist in this model.

mod model;
mod meter;

pub use meter::{EnergySample, NodeMeter, RaplMeter};
pub use model::{standard_power, OpPointPower, PowerModel, PowerParams};
