//! A deliberately static "algorithm": fixed channel count, parallelism
//! pinned to 1, performance CPU governor, no feedback of any kind.
//!
//! This is the baseline the concurrency sweep measures (the landscape the
//! paper's FSM algorithms navigate online), expressed through the same
//! session driver as every other algorithm so the codebase has exactly
//! one stepping loop. It also serves as a simple tenant workload for
//! fleet scenarios.

use super::algorithm::{Algorithm, InitPlan};
use crate::config::Testbed;
use crate::cpusim::CpuState;
use crate::dataset::{partition_files_capped, Dataset};
use crate::sim::{Telemetry, TuneCtx};
use crate::units::SimDuration;

/// Fixed-channel, no-feedback transfer.
#[derive(Debug, Clone, Copy)]
pub struct NoTune {
    channels: u32,
}

impl NoTune {
    /// A static transfer pinned at `channels` channels.
    pub fn new(channels: u32) -> Self {
        NoTune { channels: channels.max(1) }
    }

    /// The fixed channel count.
    pub fn channels(&self) -> u32 {
        self.channels
    }
}

impl Algorithm for NoTune {
    fn name(&self) -> &'static str {
        "static"
    }

    fn timeout(&self) -> SimDuration {
        // No tuning happens; the timeout only paces telemetry draining and
        // the channel re-pin below.
        SimDuration::from_secs(1.0)
    }

    fn init(&mut self, testbed: &Testbed, dataset: &Dataset) -> InitPlan {
        // Parallelism pinned to 1 so the channel count is the only
        // concurrency knob (what the sweep isolates).
        let partitions = partition_files_capped(dataset, testbed.bdp(), 1);
        InitPlan::new(
            partitions,
            self.channels,
            CpuState::performance(testbed.client_cpu.clone()),
        )
    }

    fn on_timeout(&mut self, _telemetry: &Telemetry, ctx: &mut TuneCtx) {
        // Keep the static channel count pinned as partitions finish; the
        // CPU is never touched (performance governor).
        if ctx.engine.num_channels() < self.channels && !ctx.engine.is_done() {
            ctx.engine.update_weights();
            ctx.engine.set_num_channels(self.channels);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    #[test]
    fn init_pins_everything_static() {
        let mut a = NoTune::new(6);
        let plan = a.init(&testbeds::cloudlab(), &standard::medium_dataset(1));
        assert_eq!(plan.num_channels, 6);
        assert!(plan.client_cpu.at_max_cores() && plan.client_cpu.at_max_freq());
        for p in &plan.partitions {
            assert_eq!(p.parallelism, 1);
        }
    }

    #[test]
    fn session_holds_the_channel_count() {
        let cfg = SessionConfig::new(
            testbeds::cloudlab(),
            standard::medium_dataset(3),
            AlgorithmKind::NoTune(4),
        );
        let out = run_session(&cfg);
        assert!(out.completed);
        assert_eq!(out.peak_channels, 4, "static count must never grow");
        assert!(out.final_active_cores == testbeds::cloudlab().client_cpu.num_cores);
    }

    #[test]
    fn floors_at_one_channel() {
        assert_eq!(NoTune::new(0).channels(), 1);
    }
}
