//! Counters, gauges, percentile histograms and the per-segment
//! [`MetricsTimeline`].
//!
//! The registry is deliberately exact: a [`Histogram`] keeps every
//! recorded value (plus log2 bucket counts for shape), and percentiles
//! are computed nearest-rank over a `total_cmp`-sorted copy — so the
//! reported p50/p95/p99 are insensitive to recording order and contain
//! no floating-point summation ambiguity. Bucket boundaries come from
//! the value's IEEE-754 exponent bits (not `log2()`, whose libm
//! implementation may differ across platforms), keeping the JSON output
//! bit-deterministic for one `(config, seed, shards)` triple.
//!
//! **Shard-sensitivity carve-out.** Everything in here describes the
//! *simulated* run except the `stepper.*` series (warm-batched vs
//! slow-path tick occupancy): the warm/slow split is an implementation
//! detail of the driver — the serial 1-shard loop steps tick-at-a-time
//! while the sharded path batches warm epochs — so those counters are
//! deliberately metrics-only (never traced) and are excluded from
//! shard-invariance comparisons. See ARCHITECTURE §Observability.

use std::collections::BTreeMap;

use crate::history::json;

/// Version written into the metrics JSON document (`"v"`).
pub const METRICS_FORMAT_VERSION: u32 = 1;

/// An exact-percentile histogram with log2 bucket counts.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Histogram {
    /// Every finite recorded value, in recording order.
    values: Vec<f64>,
}

/// Bucket key: IEEE-754 exponent of the value (so the bucket covers
/// `[2^e, 2^(e+1))`), `i64::MIN` for values ≤ 0 or subnormal.
fn bucket_exp(x: f64) -> i64 {
    if x <= 0.0 {
        return i64::MIN;
    }
    let biased = (x.to_bits() >> 52) & 0x7ff;
    if biased == 0 {
        return i64::MIN; // subnormal: lump with the ≤0 bucket
    }
    biased as i64 - 1023
}

impl Histogram {
    /// Record one sample; non-finite values are dropped (counted by
    /// nothing — NaN must never poison a percentile, see
    /// `metrics::Summary` for the same policy).
    pub fn record(&mut self, x: f64) {
        if x.is_finite() {
            self.values.push(x);
        }
    }

    /// Recorded (finite) sample count.
    pub fn count(&self) -> usize {
        self.values.len()
    }

    /// Smallest recorded value.
    pub fn min(&self) -> Option<f64> {
        self.values.iter().copied().min_by(|a, b| a.total_cmp(b))
    }

    /// Largest recorded value.
    pub fn max(&self) -> Option<f64> {
        self.values.iter().copied().max_by(|a, b| a.total_cmp(b))
    }

    /// Arithmetic mean of the recorded values.
    pub fn mean(&self) -> Option<f64> {
        if self.values.is_empty() {
            return None;
        }
        Some(self.values.iter().sum::<f64>() / self.values.len() as f64)
    }

    /// Exact nearest-rank percentile (`q` in `[0, 1]`) over a
    /// `total_cmp`-sorted copy; `None` when empty.
    pub fn percentile(&self, q: f64) -> Option<f64> {
        if self.values.is_empty() {
            return None;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
        Some(sorted[idx - 1])
    }

    /// Log2 bucket counts as `(upper_bound, count)` pairs, ascending.
    /// The bucket for values ≤ 0 reports an upper bound of 0.
    pub fn buckets(&self) -> Vec<(f64, u64)> {
        let mut counts: BTreeMap<i64, u64> = BTreeMap::new();
        for &x in &self.values {
            *counts.entry(bucket_exp(x)).or_insert(0) += 1;
        }
        counts
            .into_iter()
            .map(|(e, n)| {
                let hi = if e == i64::MIN { 0.0 } else { 2f64.powi((e + 1) as i32) };
                (hi, n)
            })
            .collect()
    }

    /// One JSON object: count, min/mean/max, exact p50/p95/p99 and the
    /// log2 buckets (`[[upper_bound, count], …]`).
    pub fn to_json(&self) -> String {
        let opt = |v: Option<f64>| v.map(json::num).unwrap_or_else(|| "null".to_string());
        let buckets: Vec<String> = self
            .buckets()
            .iter()
            .map(|(hi, n)| format!("[{},{}]", json::num(*hi), n))
            .collect();
        format!(
            "{{\"count\":{},\"min\":{},\"mean\":{},\"p50\":{},\"p95\":{},\"p99\":{},\
             \"max\":{},\"buckets\":[{}]}}",
            self.count(),
            opt(self.min()),
            opt(self.mean()),
            opt(self.percentile(0.50)),
            opt(self.percentile(0.95)),
            opt(self.percentile(0.99)),
            opt(self.max()),
            buckets.join(",")
        )
    }
}

/// Named counters, gauges and histograms (`BTreeMap`s keep every JSON
/// rendering deterministically key-ordered).
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

impl MetricsRegistry {
    /// An empty registry.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Add `by` to counter `name` (created at 0).
    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_insert(0) += by;
    }

    /// Current value of counter `name` (0 when never incremented).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Set gauge `name` to `v` (last write wins).
    pub fn set_gauge(&mut self, name: &str, v: f64) {
        self.gauges.insert(name.to_string(), v);
    }

    /// Current value of gauge `name`.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Record one sample into histogram `name` (created empty).
    pub fn record(&mut self, name: &str, v: f64) {
        self.histograms.entry(name.to_string()).or_default().record(v);
    }

    /// Histogram `name`, if any samples were ever recorded into it.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.get(name)
    }

    /// Iterate histograms in name order.
    pub fn histograms(&self) -> impl Iterator<Item = (&str, &Histogram)> {
        self.histograms.iter().map(|(k, v)| (k.as_str(), v))
    }

    /// The `"histograms"` JSON object alone (embedded by `BENCH_*.json`
    /// reports as well as the full metrics document).
    pub fn histograms_json(&self) -> String {
        let entries: Vec<String> = self
            .histograms
            .iter()
            .map(|(k, h)| format!("\"{}\":{}", json::escape(k), h.to_json()))
            .collect();
        format!("{{{}}}", entries.join(","))
    }

    /// The full registry as one JSON object.
    pub fn to_json(&self) -> String {
        let counters: Vec<String> = self
            .counters
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", json::escape(k), v))
            .collect();
        let gauges: Vec<String> = self
            .gauges
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", json::escape(k), json::num(*v)))
            .collect();
        format!(
            "{{\"counters\":{{{}}},\"gauges\":{{{}}},\"histograms\":{}}}",
            counters.join(","),
            gauges.join(","),
            self.histograms_json()
        )
    }
}

/// One fleet-level snapshot, taken at a dispatcher segment boundary.
#[derive(Debug, Clone, PartialEq)]
pub struct SegmentSnapshot {
    /// Simulated time of the boundary, seconds.
    pub t_secs: f64,
    /// Sessions actively transferring across the fleet.
    pub active_sessions: u64,
    /// Sessions waiting in the admission queue (FIFO + deferred).
    pub queued: u64,
    /// Fleet goodput over the segment: Δbytes / Δt.
    pub goodput_bps: f64,
    /// Fleet client power over the segment: Δjoules / Δt.
    pub watts: f64,
    /// Ticks the segment advanced through warm-epoch batching
    /// (shard-sensitive — see the module docs).
    pub warm_ticks: u64,
    /// Ticks the segment advanced one at a time on the slow path.
    pub slow_ticks: u64,
}

impl SegmentSnapshot {
    fn to_json(&self) -> String {
        format!(
            "{{\"t\":{},\"active\":{},\"queued\":{},\"goodput_bps\":{},\"watts\":{},\
             \"warm_ticks\":{},\"slow_ticks\":{}}}",
            json::num(self.t_secs),
            self.active_sessions,
            self.queued,
            json::num(self.goodput_bps),
            json::num(self.watts),
            self.warm_ticks,
            self.slow_ticks
        )
    }
}

/// The per-segment snapshot series.
#[derive(Debug, Clone, Default)]
pub struct MetricsTimeline {
    /// Snapshots in boundary order.
    pub snapshots: Vec<SegmentSnapshot>,
}

impl MetricsTimeline {
    /// Render the timeline as CSV (`greendt fleet --metrics-csv`), one
    /// row per segment boundary with the same fields — and the same
    /// shortest-round-trip float rendering — as the JSON document, so
    /// spreadsheet tooling shares the exports' bit-determinism.
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("t_secs,active_sessions,queued,goodput_bps,watts,warm_ticks,slow_ticks\n");
        for s in &self.snapshots {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                json::num(s.t_secs),
                s.active_sessions,
                s.queued,
                json::num(s.goodput_bps),
                json::num(s.watts),
                s.warm_ticks,
                s.slow_ticks
            ));
        }
        out
    }
}

/// Everything `--metrics` collects: the registry plus the timeline.
#[derive(Debug, Clone, Default)]
pub struct FleetMetrics {
    /// Counters, gauges and histograms.
    pub registry: MetricsRegistry,
    /// Per-segment fleet snapshots.
    pub timeline: MetricsTimeline,
}

impl FleetMetrics {
    /// Warm-batched share of all advanced ticks (`None` before any tick).
    pub fn warm_hit_rate(&self) -> Option<f64> {
        let warm = self.registry.counter("stepper.warm_ticks");
        let slow = self.registry.counter("stepper.slow_ticks");
        let total = warm + slow;
        if total == 0 {
            return None;
        }
        Some(warm as f64 / total as f64)
    }

    /// The versioned metrics JSON document (`greendt fleet --metrics`).
    pub fn to_json(&self) -> String {
        let rows: Vec<String> =
            self.timeline.snapshots.iter().map(SegmentSnapshot::to_json).collect();
        format!(
            "{{\n  \"v\": {},\n  \"kind\": \"greendt-metrics\",\n  \"registry\": {},\n  \
             \"timeline\": [\n    {}\n  ]\n}}\n",
            METRICS_FORMAT_VERSION,
            self.registry.to_json(),
            rows.join(",\n    ")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_is_all_none() {
        let h = Histogram::default();
        assert_eq!(h.count(), 0);
        assert_eq!(h.percentile(0.5), None);
        assert_eq!(h.min(), None);
        assert_eq!(h.mean(), None);
        assert!(h.to_json().contains("\"p50\":null"));
    }

    #[test]
    fn nan_and_infinity_are_dropped() {
        let mut h = Histogram::default();
        h.record(f64::NAN);
        h.record(f64::INFINITY);
        h.record(3.0);
        assert_eq!(h.count(), 1);
        assert_eq!(h.percentile(0.99), Some(3.0));
    }

    #[test]
    fn percentiles_are_exact_and_order_insensitive() {
        let mut fwd = Histogram::default();
        let mut rev = Histogram::default();
        for i in 1..=100 {
            fwd.record(i as f64);
            rev.record((101 - i) as f64);
        }
        assert_eq!(fwd.percentile(0.5), Some(50.0));
        assert_eq!(fwd.percentile(0.95), Some(95.0));
        assert_eq!(fwd.percentile(0.99), Some(99.0));
        assert_eq!(fwd.to_json(), rev.to_json(), "recording order must not matter");
    }

    #[test]
    fn buckets_are_log2_with_a_nonpositive_bucket() {
        let mut h = Histogram::default();
        h.record(0.0);
        h.record(-1.0);
        h.record(1.5); // [1, 2)
        h.record(3.0); // [2, 4)
        h.record(3.9); // [2, 4)
        let b = h.buckets();
        assert_eq!(b, vec![(0.0, 2), (2.0, 1), (4.0, 2)]);
    }

    #[test]
    fn registry_counts_and_records() {
        let mut r = MetricsRegistry::new();
        r.inc("sessions.admitted", 1);
        r.inc("sessions.admitted", 2);
        r.set_gauge("fleet.hosts", 4.0);
        r.record("queue.wait_s", 1.0);
        r.record("queue.wait_s", 9.0);
        assert_eq!(r.counter("sessions.admitted"), 3);
        assert_eq!(r.counter("never"), 0);
        assert_eq!(r.gauge("fleet.hosts"), Some(4.0));
        assert_eq!(r.histogram("queue.wait_s").unwrap().count(), 2);
        let j = r.to_json();
        assert!(j.contains("\"sessions.admitted\":3"));
        assert!(j.contains("\"queue.wait_s\""));
        assert!(crate::history::json::parse(&j).is_some(), "registry JSON parses: {j}");
    }

    #[test]
    fn fleet_metrics_document_parses_and_reports_hit_rate() {
        let mut m = FleetMetrics::default();
        assert_eq!(m.warm_hit_rate(), None);
        m.registry.inc("stepper.warm_ticks", 30);
        m.registry.inc("stepper.slow_ticks", 10);
        m.timeline.snapshots.push(SegmentSnapshot {
            t_secs: 3.0,
            active_sessions: 2,
            queued: 1,
            goodput_bps: 1e8,
            watts: 40.0,
            warm_ticks: 30,
            slow_ticks: 10,
        });
        assert_eq!(m.warm_hit_rate(), Some(0.75));
        let doc = m.to_json();
        assert!(crate::history::json::parse(&doc).is_some(), "metrics JSON parses: {doc}");
        assert!(doc.contains("\"kind\": \"greendt-metrics\""));
        assert!(doc.contains("\"warm_ticks\":30"));
    }

    #[test]
    fn timeline_csv_matches_snapshots() {
        let mut tl = MetricsTimeline::default();
        assert_eq!(tl.to_csv().lines().count(), 1, "header only when empty");
        tl.snapshots.push(SegmentSnapshot {
            t_secs: 3.5,
            active_sessions: 2,
            queued: 1,
            goodput_bps: 1e8,
            watts: 40.25,
            warm_ticks: 30,
            slow_ticks: 10,
        });
        let csv = tl.to_csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next(),
            Some("t_secs,active_sessions,queued,goodput_bps,watts,warm_ticks,slow_ticks")
        );
        assert_eq!(lines.next(), Some("3.5,2,1,100000000,40.25,30,10"));
        assert_eq!(lines.next(), None);
    }
}
