//! Contended networks: seeded cross-traffic and AIMD competing flows.
//!
//!     cargo run --release --example contended_link
//!
//! The same two-tenant fleet runs three times on one CloudLab host:
//! once on the quiet path (the OU background alone), once with seeded
//! cross-traffic generators — a steady 10 % UDP floor plus bursty
//! mgen-style TCP flows — stealing part of the bottleneck, and once
//! contended *and* with the per-channel FSM switched from
//! slow-start-then-hold to AIMD (additive increase per RTT,
//! multiplicative decrease on overload). The contended runs are exactly
//! reproducible: the generators draw from their own seeded RNG stream.
//!
//! The CLI spells the same thing
//! `greendt fleet --cross-traffic "udp:0.1;tcp:0.3:20e6:1" --aimd`.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, FleetPolicyKind};
use greendt::dataset::standard;
use greendt::netsim::CrossTrafficConfig;
use greendt::sim::fleet::{run_fleet, FleetConfig, FleetOutcome, TenantSpec};
use greendt::units::SimTime;

fn two_tenant_cfg() -> FleetConfig {
    let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
        .with_seed(42);
    for (i, name) in ["alpha", "beta"].iter().enumerate() {
        let ds = standard::medium_dataset(42 + i as u64);
        cfg.tenants.push(
            TenantSpec::new(*name, ds, AlgorithmKind::MinEnergy)
                .arriving_at(SimTime::from_secs(15.0 * i as f64)),
        );
    }
    cfg
}

fn report(label: &str, out: &FleetOutcome) {
    println!(
        "  {label:<18} makespan {:>8}  moved {:>9}  energy {:>10}  Jain {:.3}",
        format!("{}", out.duration),
        format!("{}", out.moved),
        format!("{}", out.client_energy),
        out.jain_fairness()
    );
}

fn main() {
    let cross = CrossTrafficConfig {
        udp_fraction: 0.10,
        tcp_rate_per_sec: 0.3,
        tcp_burst_bytes: 20e6,
        tcp_burst_secs: 1.0,
    };

    println!("contended link — two MinEnergy tenants on CloudLab (1 Gbps)\n");

    let quiet = run_fleet(&two_tenant_cfg());
    report("quiet", &quiet);

    let contended = run_fleet(&two_tenant_cfg().with_cross_traffic(cross));
    report("contended", &contended);

    let contended_aimd =
        run_fleet(&two_tenant_cfg().with_cross_traffic(cross).with_aimd(true));
    report("contended + aimd", &contended_aimd);

    assert!(quiet.completed && contended.completed && contended_aimd.completed);
    assert!(
        contended.duration.as_secs() > quiet.duration.as_secs(),
        "the generators must steal real bandwidth"
    );

    // Same seed, same bits: the stochastic load is exactly replayable.
    let again = run_fleet(&two_tenant_cfg().with_cross_traffic(cross).with_aimd(true));
    assert_eq!(
        contended_aimd.duration.as_secs().to_bits(),
        again.duration.as_secs().to_bits(),
        "contended runs are a pure function of the seed"
    );

    println!(
        "\n  cross-traffic slows the fleet by {:.0}% and the contended run \
         replays bit-for-bit under its seed",
        100.0 * (contended.duration.as_secs() / quiet.duration.as_secs() - 1.0)
    );
}
