//! Quickstart: one energy-efficient transfer, five lines of setup.
//!
//!     cargo run --release --example quickstart
//!
//! Runs the Energy-Efficient Maximum Throughput algorithm (Alg. 5 +
//! load control, Alg. 3) moving the paper's medium dataset (Table II)
//! over the CloudLab testbed (Table I), and prints what the paper's
//! figures would plot for this cell.

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::sim::session::{run_session, SessionConfig};

fn main() {
    let testbed = testbeds::cloudlab();
    let dataset = standard::medium_dataset(42);
    let cfg = SessionConfig::new(testbed, dataset, AlgorithmKind::MaxThroughput);

    let out = run_session(&cfg);

    println!("GreenDT quickstart — EEMT on CloudLab, medium dataset");
    println!("  moved          : {}", out.moved);
    println!("  duration       : {}", out.duration);
    println!("  avg throughput : {}", out.avg_throughput);
    println!("  client energy  : {}", out.client_energy);
    println!("  server energy  : {}", out.server_energy);
    println!("  final CPU      : {} cores @ {}", out.final_active_cores, out.final_freq);
    assert!(out.completed, "transfer must complete");
}
