"""Shared tensor layouts for the GreenDT predictor.

This file is the single source of truth for the interchange format between
Layer 2 (the JAX model, AOT-compiled to `artifacts/predictor.hlo.txt`) and
Layer 3 (the Rust coordinator, `rust/src/predictor/layout.rs` mirrors these
constants — keep the two in sync; the `predictor_parity` integration test
executes the artifact against the Rust oracle and fails on drift).

Inputs
------
``cand``: float32[NUM_CANDIDATES, CAND_WIDTH]
    Per-candidate operating points: (channels, active_cores, freq_ghz).
    Unused rows are padded with zeros; a zero-core candidate yields zero
    throughput and +inf-ish energy, so padding never wins the argmin.

``state``: float32[STATE_WIDTH]
    Scalars describing the transfer + platform at this instant.

Output
------
float32[NUM_CANDIDATES, OUT_WIDTH]: (throughput_Bps, power_W, energy_J).
"""

# Grid sizing: 8-16 cores x ~12 P-states fits comfortably; the kernel is
# tiled in TILE-row blocks along the candidate axis.
NUM_CANDIDATES = 128
TILE = 32

CAND_WIDTH = 3
CAND_CHANNELS = 0
CAND_CORES = 1
CAND_FREQ_GHZ = 2

STATE_WIDTH = 24
S_CAPACITY_BPS = 0  # available bottleneck capacity, bytes/s (bg deducted)
S_RTT_S = 1
S_AVG_WIN_BYTES = 2
S_KNEE_STREAMS = 3
S_OVERLOAD_GAMMA = 4
S_OVERLOAD_FLOOR = 5
S_PARALLELISM = 6  # streams per channel
S_REMAINING_BYTES = 7
S_AVG_FILE_BYTES = 8
S_PP_LEVEL = 9
S_CYCLES_PER_BYTE = 10
S_CYCLES_PER_REQ = 11
S_CYCLES_PER_STREAM = 12
S_MAX_APP_UTIL = 13
S_PKG_STATIC_W = 14
S_CORE_IDLE_BASE_W = 15
S_CORE_IDLE_PER_GHZ_W = 16
S_DYN_KAPPA = 17
S_V_MIN = 18
S_V_MAX = 19
S_F_MIN_GHZ = 20
S_F_MAX_GHZ = 21
S_DRAM_W_PER_GBS = 22
S_RESERVED = 23

OUT_WIDTH = 3
OUT_TPUT_BPS = 0
OUT_POWER_W = 1
OUT_ENERGY_J = 2
