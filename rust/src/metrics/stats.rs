//! Summary statistics over f64 samples (used by benches and reports).
//!
//! Robustness contract (ISSUE 9 satellite): non-finite samples (NaN,
//! ±inf) are dropped before any arithmetic, sorting uses the IEEE-754
//! total order (no `partial_cmp().unwrap()` panic path), and callers
//! who need to distinguish "no usable samples" from real zeros use
//! [`Summary::try_of`], which returns `None` instead of a zeroed
//! summary. Every field of a returned summary is finite.

/// Mean / spread / percentile summary of a sample set.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Sample count (finite samples only).
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Median.
    pub p50: f64,
    /// 95th percentile.
    pub p95: f64,
    /// 99th percentile.
    pub p99: f64,
    /// Largest sample.
    pub max: f64,
}

impl Summary {
    /// Compute a summary. Returns a zeroed summary when no finite
    /// samples remain (empty input, or all-NaN/inf input).
    pub fn of(samples: &[f64]) -> Summary {
        Summary::try_of(samples).unwrap_or(Summary {
            n: 0,
            mean: 0.0,
            std: 0.0,
            min: 0.0,
            p50: 0.0,
            p95: 0.0,
            p99: 0.0,
            max: 0.0,
        })
    }

    /// Compute a summary, or `None` when no finite samples remain.
    ///
    /// NaN and infinite inputs are filtered out rather than propagated;
    /// `n` counts only the samples that survived the filter.
    pub fn try_of(samples: &[f64]) -> Option<Summary> {
        let mut sorted: Vec<f64> = samples.iter().copied().filter(|x| x.is_finite()).collect();
        let n = sorted.len();
        if n == 0 {
            return None;
        }
        sorted.sort_by(|a, b| a.total_cmp(b));
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Some(Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            p50: percentile(&sorted, 0.50),
            p95: percentile(&sorted, 0.95),
            p99: percentile(&sorted, 0.99),
            max: sorted[n - 1],
        })
    }
}

/// Nearest-rank percentile on a pre-sorted slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len());
    sorted[idx - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_data() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
    }

    #[test]
    fn empty_is_zeroed() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.mean, 0.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[42.0]);
        assert_eq!(s.p50, 42.0);
        assert_eq!(s.p99, 42.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    fn unsorted_input_ok() {
        let s = Summary::of(&[3.0, 1.0, 2.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn try_of_empty_is_none() {
        assert_eq!(Summary::try_of(&[]), None);
    }

    #[test]
    fn nan_inputs_are_dropped_not_propagated() {
        let s = Summary::of(&[f64::NAN, 1.0, 2.0, f64::NAN, 3.0]);
        assert_eq!(s.n, 3, "only finite samples counted");
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.max, 3.0);
        assert!(s.mean.is_finite() && s.std.is_finite());
    }

    #[test]
    fn infinities_are_dropped() {
        let s = Summary::of(&[f64::INFINITY, 5.0, f64::NEG_INFINITY]);
        assert_eq!(s.n, 1);
        assert_eq!(s.min, 5.0);
        assert_eq!(s.max, 5.0);
        assert!(s.mean.is_finite());
    }

    #[test]
    fn all_nan_is_none_not_panic() {
        assert_eq!(Summary::try_of(&[f64::NAN, f64::NAN]), None);
        let s = Summary::of(&[f64::NAN]);
        assert_eq!(s.n, 0);
        assert_eq!(s.p99, 0.0);
    }
}
