//! Observations exposed to the tuning algorithms.

use crate::units::{Bytes, Energy, Power, Rate, SimDuration, SimTime};

/// Instantaneous statistics from one simulation tick.
#[derive(Debug, Clone, Copy, Default)]
pub struct TickStats {
    /// Application goodput this tick.
    pub goodput: Rate,
    /// Bytes moved this tick.
    pub moved: Bytes,
    /// Client CPU load (0..∞).
    pub client_load: f64,
    /// Server CPU load (0..∞).
    pub server_load: f64,
    /// Client package power.
    pub client_power: Power,
    /// Server package power.
    pub server_power: Power,
    /// TCP streams open across all sessions.
    pub open_streams: usize,
    /// True when an active session's transfer finished on this tick — the
    /// event-horizon drivers end their inner tick loop here so departures
    /// are handled on exactly the tick the reference driver would.
    pub session_completed: bool,
}

/// Network-side view exposed to the predictive governor: the path model
/// the application maintains (bandwidth/RTT probes à la iperf plus its own
/// transfer bookkeeping).
#[derive(Debug, Clone, Copy, Default)]
pub struct NetView {
    /// Estimated available bottleneck capacity, bytes/s.
    pub available_bps: f64,
    /// Path round-trip time, seconds.
    pub rtt_s: f64,
    /// Mean steady-state TCP window, bytes.
    pub avg_win_bytes: f64,
    /// Stream count where overload sets in.
    pub knee_streams: f64,
    /// Overload penalty slope.
    pub overload_gamma: f64,
    /// Overload penalty floor.
    pub overload_floor: f64,
    /// Average streams per channel across open channels.
    pub parallelism: f64,
    /// Remaining-weighted average file size, bytes.
    pub avg_file_bytes: f64,
    /// Remaining-weighted pipelining level.
    pub pp_level: f64,
}

/// Aggregated observations over one tuning interval — everything the
/// paper's algorithms read (`calculateThroughput()`, `calculateEnergy()`,
/// `cpuLoad`, remaining data).
#[derive(Debug, Clone, Copy)]
pub struct Telemetry {
    /// When the interval ended.
    pub now: SimTime,
    /// Average application throughput over the interval.
    pub avg_throughput: Rate,
    /// Client-side energy consumed during the interval (package, or wall
    /// if the testbed uses a wall meter).
    pub interval_energy: Energy,
    /// Average client power over the interval.
    pub avg_power: Power,
    /// Mean client CPU load over the interval (0..∞; >1 = saturated).
    pub cpu_load: f64,
    /// Data still to move.
    pub remaining: Bytes,
    /// Total session size.
    pub total: Bytes,
    /// Session time elapsed.
    pub elapsed: SimDuration,
    /// Channels currently open.
    pub num_channels: u32,
    /// TCP streams currently open.
    pub open_streams: usize,
    /// Path/transfer model for predictive control.
    pub net: NetView,
}

impl Telemetry {
    /// `remainTime = remainData / avgThroughput` (Alg. 4 line 5); infinite
    /// when nothing is moving.
    pub fn remaining_time(&self) -> SimDuration {
        let bps = self.avg_throughput.as_bytes_per_sec();
        if bps <= 0.0 {
            SimDuration::from_secs(f64::INFINITY)
        } else {
            SimDuration::from_secs(self.remaining.as_f64() / bps)
        }
    }

    /// `predictedEnergy = avgPower × remainTime` (Alg. 4 line 6).
    pub fn predicted_future_energy(&self) -> Energy {
        let t = self.remaining_time().as_secs();
        if t.is_infinite() {
            Energy::from_joules(f64::MAX / 4.0)
        } else {
            Energy::from_joules(self.avg_power.as_watts() * t)
        }
    }

    /// Fraction of the session already moved.
    pub fn progress(&self) -> f64 {
        1.0 - self.remaining.fraction_of(self.total)
    }
}

/// One host's score sheet inside a [`DispatchRecord`] — the quantities
/// the placement policy compared when a session was dispatched. Exposed
/// so placement decisions can be mined offline (historical-log-driven
/// tuning, arXiv:2104.01192): every record carries enough context to
/// replay or second-guess the choice.
#[derive(Debug, Clone)]
pub struct PlacementScore {
    /// Host name (its [`crate::sim::dispatcher::HostSpec`] name).
    pub host: String,
    /// Sessions active on the host when the decision was made.
    pub active_sessions: u32,
    /// Predicted whole-host instrument power at the current session
    /// count, W.
    pub current_power_w: f64,
    /// Predicted whole-host instrument power with the new session, W.
    pub projected_power_w: f64,
    /// Expected goodput of the new session if placed here, bytes/s.
    pub projected_session_bps: f64,
    /// Marginal energy per byte: `(projected − current) / goodput`, J/B.
    pub marginal_j_per_byte: f64,
    /// Queueing-delay price added to the ranking when the dispatcher runs
    /// with queue-delay pricing (see
    /// [`DispatcherConfig::price_queue_delay`](crate::sim::dispatcher::DispatcherConfig)):
    /// the expected extra seconds-per-byte this placement suffers from
    /// contention on the host, converted to J/B at the host's idle draw.
    /// Zero when pricing is off or the host is idle.
    pub queue_delay_j_per_byte: f64,
    /// History-observed J/B for a workload like this on this host, when a
    /// [`KnnIndex`](crate::history::KnnIndex) was attached to the run and
    /// had relevant records (`None` otherwise). What
    /// [`PlacementKind::Learned`](crate::coordinator::fleet::PlacementKind)
    /// blended into the score.
    pub learned_j_per_byte: Option<f64>,
}

/// One dispatcher decision: which host (if any) an arriving session was
/// placed on, with the per-host scores that drove the choice — the
/// telemetry surface of [`crate::sim::dispatcher::run_dispatcher`].
#[derive(Debug, Clone)]
pub struct DispatchRecord {
    /// When the decision was made (simulated clock), seconds.
    pub t_secs: f64,
    /// Session name.
    pub session: String,
    /// When the session originally asked to run, seconds (equals
    /// `t_secs` unless it sat in the admission queue first).
    pub requested_at_secs: f64,
    /// Index of the host the session was admitted to, or `None` if it
    /// was queued by admission control.
    pub admitted_host: Option<usize>,
    /// Name of the admitting host (`None` while queued).
    pub host: Option<String>,
    /// Projected aggregate fleet power after this decision, W — for an
    /// admission, the value admission control compared against the power
    /// cap; for a queueing, the best (lowest) projection among hosts with
    /// a free slot, i.e. the one that still broke the cap.
    pub projected_fleet_power_w: f64,
    /// Per-host scores at decision time, indexed like the dispatcher's
    /// host list.
    pub scores: Vec<PlacementScore>,
}

impl DispatchRecord {
    /// True when this decision queued the session instead of admitting.
    pub fn queued(&self) -> bool {
        self.admitted_host.is_none()
    }

    /// How long the session waited between requesting and this decision.
    pub fn waited_secs(&self) -> f64 {
        (self.t_secs - self.requested_at_secs).max(0.0)
    }
}

/// One live migration executed by the fleet rebalancer
/// ([`crate::rebalance`]): a running session preempted on one host and
/// its remaining bytes re-admitted on another after a drain delay. Sits
/// next to [`DispatchRecord`] in
/// [`DispatchOutcome`](crate::sim::dispatcher::DispatchOutcome) and is
/// persisted to the history log as its own record kind, so moves can be
/// mined offline alongside the placement decisions they second-guess.
#[derive(Debug, Clone)]
pub struct MigrationRecord {
    /// When the session was preempted (simulated clock), seconds.
    pub t_secs: f64,
    /// Session name (unchanged across the move — its partial and resumed
    /// outcomes share it).
    pub session: String,
    /// Index of the source host.
    pub from_host: usize,
    /// Name of the source host.
    pub from: String,
    /// Index of the target host the remaining bytes re-admit on. The
    /// rebalancer's planned target at preemption time, corrected to the
    /// actual admitting host if the fleet changed during the drain and
    /// re-admission landed elsewhere; a migrated session still unplaced
    /// when the run ends keeps the plan (and appears in `unplaced`).
    pub to_host: usize,
    /// Name of the target host (same correction rule as
    /// [`Self::to_host`]).
    pub to: String,
    /// Bytes the session had already delivered on the source.
    pub moved_bytes: f64,
    /// Bytes re-admitted on the target (byte conservation:
    /// `moved_bytes + remaining_bytes` equals the session's original
    /// dataset size).
    pub remaining_bytes: f64,
    /// Drain/handoff delay the move paid, seconds.
    pub drain_secs: f64,
    /// When the remaining bytes were due to re-admit, seconds
    /// (`t_secs + drain_secs`).
    pub resume_at_secs: f64,
    /// The rebalancer's estimated saving on the remaining bytes, J (may
    /// be negative for cap-pressure moves).
    pub est_benefit_j: f64,
    /// The rebalancer's estimated cost of the move itself, J.
    pub est_cost_j: f64,
    /// Id of the rebalance policy that proposed the move.
    pub policy: &'static str,
}

/// One scripted fault firing at a dispatcher segment boundary — the
/// telemetry surface of the resilience pipeline's injection side. One
/// record per [`FaultAction`](crate::resilience::FaultAction) fired, in
/// firing order.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    /// When the fault fired (simulated clock), seconds.
    pub t_secs: f64,
    /// Index of the host the fault targeted.
    pub host: usize,
    /// Name of that host.
    pub host_name: String,
    /// What happened.
    pub kind: crate::resilience::FaultKind,
    /// Running sessions the fault hit (preempted-and-retried or
    /// dead-lettered for a host death; 0 for link events, which kill
    /// nothing directly).
    pub sessions_hit: u32,
}

/// One retry scheduled by the resilience pipeline: a session lost to a
/// host failure, parked in the PenaltyBox, due to re-enter placement
/// after its backoff. Its eventual re-admission emits an ordinary
/// [`DispatchRecord`] (with a fresh slow-start ramp), so the pair
/// tells the session's full recovery story.
#[derive(Debug, Clone)]
pub struct RetryRecord {
    /// When the session was lost (simulated clock), seconds.
    pub t_secs: f64,
    /// Session name.
    pub session: String,
    /// Index of the host that failed under it.
    pub from_host: usize,
    /// Name of that host.
    pub from: String,
    /// Which attempt this loss consumed (1 = first failure).
    pub attempt: u32,
    /// PenaltyBox backoff the retry waits, seconds.
    pub backoff_secs: f64,
    /// When the retry re-enters placement, seconds
    /// (`t_secs + backoff_secs`).
    pub resume_at_secs: f64,
    /// Bytes the session still owes (re-materialized, never
    /// teleported: the retried dataset carries exactly these bytes).
    pub remaining_bytes: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tel() -> Telemetry {
        Telemetry {
            now: SimTime::from_secs(10.0),
            avg_throughput: Rate::from_bytes_per_sec(100e6),
            interval_energy: Energy::from_joules(90.0),
            avg_power: Power::from_watts(30.0),
            cpu_load: 0.5,
            remaining: Bytes::from_gb(1.0),
            total: Bytes::from_gb(4.0),
            elapsed: SimDuration::from_secs(10.0),
            num_channels: 4,
            open_streams: 8,
            net: NetView::default(),
        }
    }

    #[test]
    fn remaining_time_divides() {
        assert!((tel().remaining_time().as_secs() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn predicted_energy_is_power_times_time() {
        assert!((tel().predicted_future_energy().as_joules() - 300.0).abs() < 1e-9);
    }

    #[test]
    fn stalled_transfer_predicts_huge_energy() {
        let mut t = tel();
        t.avg_throughput = Rate::ZERO;
        assert!(t.remaining_time().as_secs().is_infinite());
        assert!(t.predicted_future_energy().as_joules() > 1e100);
    }

    #[test]
    fn progress_fraction() {
        assert!((tel().progress() - 0.75).abs() < 1e-9);
    }
}
