//! Decision calibration: join predicted scores against realized
//! outcomes (ISSUE 10).
//!
//! The dispatcher's placement and rebalance layers act on *predicted*
//! joules-per-byte (`PlacementScore::marginal_j_per_byte`, the
//! rebalancer's `est_benefit_j`/`est_cost_j`), but nothing upstream of
//! this module measured how those predictions square with what the
//! fleet actually delivered. The calibration ledger closes that loop:
//! every residency close joins the admission-time prediction against
//! the realized bytes/joules — read with the *identical* expressions
//! [`crate::sim::FleetOutcome`] bills tenants with, so the ledger's
//! realized side reconciles with the outcome to the bit (pinned in
//! `rust/tests/calibration_diff.rs`).
//!
//! Three artifact kinds come out:
//!
//! * **[`CalibrationRecord`]** — one per residency, carrying the
//!   predicted marginal J/B next to the realized J/B;
//! * **[`MigrationCalibration`]** — one per executed move, the cost
//!   model's estimated net joules next to the realized J/B drop between
//!   the source and target residencies of the same session;
//! * **[`CalibrationAnomaly`]** — residencies whose realized J/B
//!   deviates from the prediction beyond
//!   [`CalibrationConfig::anomaly_factor`] (also emitted as
//!   `calibration_anomaly` instant events when the trace is on).
//!
//! The collector additionally derives two watchdogs from the same
//! segment-boundary data: a starved-queue alarm (sessions queued with
//! no admission for [`CalibrationConfig::starve_secs`]) and a
//! fairness-drop alarm (per-host delivered-byte [`jain_index`] under
//! [`CalibrationConfig::fairness_floor`]). Both are edge-triggered
//! instant events plus `watchdog.*` counters.
//!
//! Everything here is derived at segment boundaries from
//! shard-invariant inputs, so ledger, histograms and events all honor
//! the `--shards` 1/2/8 byte-identity contract of
//! `rust/tests/trace_determinism.rs`.

use crate::history::json;
use crate::metrics::Table;

/// Knobs for the calibration ledger and its watchdogs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CalibrationConfig {
    /// Flag a residency whose realized J/B is more than this factor
    /// above — or below `1/factor` of — its predicted marginal J/B.
    pub anomaly_factor: f64,
    /// Alarm when sessions sit queued this many simulated seconds with
    /// no admission at all.
    pub starve_secs: f64,
    /// Alarm when the per-host delivered-byte Jain index of a segment
    /// drops below this floor (with at least two hosts active).
    pub fairness_floor: f64,
}

impl Default for CalibrationConfig {
    fn default() -> CalibrationConfig {
        CalibrationConfig { anomaly_factor: 4.0, starve_secs: 300.0, fairness_floor: 0.4 }
    }
}

impl CalibrationConfig {
    /// The default knobs (factor 4, 300 s starvation, 0.4 fairness).
    pub fn new() -> CalibrationConfig {
        CalibrationConfig::default()
    }

    /// Set the anomaly deviation factor (values ≤ 1 flag everything).
    pub fn with_anomaly_factor(mut self, factor: f64) -> CalibrationConfig {
        self.anomaly_factor = factor;
        self
    }

    /// Set the starved-queue alarm threshold, simulated seconds.
    pub fn with_starve_secs(mut self, secs: f64) -> CalibrationConfig {
        self.starve_secs = secs;
        self
    }

    /// Set the fairness-drop alarm floor (a Jain index in `(0, 1]`).
    pub fn with_fairness_floor(mut self, floor: f64) -> CalibrationConfig {
        self.fairness_floor = floor;
        self
    }
}

/// One residency's prediction-vs-realized join, produced at residency
/// close with the same byte/joule reads [`crate::sim::FleetOutcome`]
/// uses.
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationRecord {
    /// Session/tenant name.
    pub session: String,
    /// Host that served the residency.
    pub host: String,
    /// How the residency ended: `complete`, `preempt` or `timecap`.
    pub end: String,
    /// Admission instant, seconds.
    pub t0_secs: f64,
    /// Close instant, seconds.
    pub t1_secs: f64,
    /// The dispatcher's marginal J/B score for the admitting host at
    /// admission time (`None` when the placement had no model score).
    pub predicted_jpb: Option<f64>,
    /// Bytes the residency delivered (bit-equal to the tenant outcome).
    pub realized_bytes: f64,
    /// Host energy attributed to the residency, joules (bit-equal to
    /// the tenant outcome).
    pub realized_joules: f64,
}

impl CalibrationRecord {
    /// Realized joules per byte (`None` for zero-byte residencies).
    pub fn realized_jpb(&self) -> Option<f64> {
        (self.realized_bytes > 0.0).then(|| self.realized_joules / self.realized_bytes)
    }

    /// `realized J/B ÷ predicted J/B` — the calibration ratio (`None`
    /// without a positive prediction or realized bytes).
    pub fn error_ratio(&self) -> Option<f64> {
        let predicted = self.predicted_jpb.filter(|p| *p > 0.0)?;
        Some(self.realized_jpb()? / predicted)
    }

    /// Signed relative error, `ratio - 1` (0 = perfectly calibrated,
    /// +1 = realized cost double the prediction).
    pub fn rel_error(&self) -> Option<f64> {
        self.error_ratio().map(|r| r - 1.0)
    }

    /// True when the record deviates beyond `factor` in either
    /// direction (realized > factor × predicted, or < predicted ÷
    /// factor).
    pub fn is_anomalous(&self, factor: f64) -> bool {
        match self.error_ratio() {
            Some(r) => r > factor || (factor > 0.0 && r < 1.0 / factor),
            None => false,
        }
    }

    fn to_json(&self) -> String {
        let opt = |v: Option<f64>| v.map(json::num).unwrap_or_else(|| "null".to_string());
        format!(
            "{{\"session\":\"{}\",\"host\":\"{}\",\"end\":\"{}\",\"t0\":{},\"t1\":{},\
             \"predicted_jpb\":{},\"realized_bytes\":{},\"realized_joules\":{},\
             \"realized_jpb\":{},\"error_ratio\":{}}}",
            json::escape(&self.session),
            json::escape(&self.host),
            json::escape(&self.end),
            json::num(self.t0_secs),
            json::num(self.t1_secs),
            opt(self.predicted_jpb),
            json::num(self.realized_bytes),
            json::num(self.realized_joules),
            opt(self.realized_jpb()),
            opt(self.error_ratio()),
        )
    }
}

/// One executed migration's cost-model estimate joined against the
/// realized J/B drop between the session's source and target
/// residencies.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationCalibration {
    /// Migrated session.
    pub session: String,
    /// Source host name.
    pub from: String,
    /// Target host name.
    pub to: String,
    /// Preemption instant, seconds.
    pub t_secs: f64,
    /// Planned re-admission instant (preemption + drain), seconds.
    pub resume_at_secs: f64,
    /// The cost model's estimated joules saved on the remaining bytes.
    pub est_benefit_j: f64,
    /// The cost model's estimated joules burned by the move.
    pub est_cost_j: f64,
    /// How late past the planned resume the session actually
    /// re-admitted, seconds (`None` when the run ended mid-drain).
    pub realized_delay_s: Option<f64>,
    /// `(source J/B − target J/B) × target bytes` — the realized
    /// benefit over what the target residency moved (`None` until both
    /// residencies closed with bytes on the meter).
    pub realized_benefit_j: Option<f64>,
}

impl MigrationCalibration {
    /// The cost model's predicted net gain, joules.
    pub fn predicted_net_j(&self) -> f64 {
        self.est_benefit_j - self.est_cost_j
    }

    /// `realized_benefit_j - est_benefit_j` (`None` until realized).
    pub fn benefit_error_j(&self) -> Option<f64> {
        self.realized_benefit_j.map(|r| r - self.est_benefit_j)
    }

    fn to_json(&self) -> String {
        let opt = |v: Option<f64>| v.map(json::num).unwrap_or_else(|| "null".to_string());
        format!(
            "{{\"session\":\"{}\",\"from\":\"{}\",\"to\":\"{}\",\"t\":{},\"resume_at\":{},\
             \"est_benefit_j\":{},\"est_cost_j\":{},\"realized_delay_s\":{},\
             \"realized_benefit_j\":{}}}",
            json::escape(&self.session),
            json::escape(&self.from),
            json::escape(&self.to),
            json::num(self.t_secs),
            json::num(self.resume_at_secs),
            json::num(self.est_benefit_j),
            json::num(self.est_cost_j),
            opt(self.realized_delay_s),
            opt(self.realized_benefit_j),
        )
    }
}

/// A flagged prediction-error outlier (see
/// [`CalibrationConfig::anomaly_factor`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CalibrationAnomaly {
    /// Session whose residency deviated.
    pub session: String,
    /// Host that served it.
    pub host: String,
    /// Residency close instant, seconds.
    pub t_secs: f64,
    /// The admission-time prediction, J/B.
    pub predicted_jpb: f64,
    /// What the residency actually cost, J/B.
    pub realized_jpb: f64,
    /// `realized ÷ predicted`.
    pub ratio: f64,
}

/// The decision calibration ledger a dispatcher run accumulates when
/// observability is on (see [`crate::sim::DispatchOutcome::calibration`]).
#[derive(Debug, Clone, Default)]
pub struct CalibrationLedger {
    /// One record per closed residency, in close order (host-index
    /// order within a segment boundary).
    pub placements: Vec<CalibrationRecord>,
    /// One record per executed migration, in execution order.
    pub migrations: Vec<MigrationCalibration>,
    /// Flagged outliers, in close order.
    pub anomalies: Vec<CalibrationAnomaly>,
}

impl CalibrationLedger {
    /// Summed realized joules over every residency record — bit-derived
    /// from the same reads [`crate::sim::FleetOutcome`] bills tenants
    /// with.
    pub fn realized_joules(&self) -> f64 {
        self.placements.iter().map(|r| r.realized_joules).sum()
    }

    /// Summed realized bytes over every residency record.
    pub fn realized_bytes(&self) -> f64 {
        self.placements.iter().map(|r| r.realized_bytes).sum()
    }

    /// Join each migration's estimate against the realized J/B of the
    /// session's source (`preempt`-ended, on `from`) and first
    /// subsequent target (on `to`) residencies. Called once by the
    /// collector after the last residency closed.
    pub fn join_migrations(&mut self) {
        for m in &mut self.migrations {
            let source = self
                .placements
                .iter()
                .filter(|r| {
                    r.session == m.session
                        && r.host == m.from
                        && r.end == "preempt"
                        && (r.t1_secs - m.t_secs).abs() < 1e-6
                })
                .last();
            let target = self
                .placements
                .iter()
                .filter(|r| r.session == m.session && r.host == m.to && r.t0_secs >= m.t_secs)
                .min_by(|a, b| a.t0_secs.total_cmp(&b.t0_secs));
            if let (Some(src), Some(tgt)) = (source, target) {
                m.realized_delay_s = Some((tgt.t0_secs - m.resume_at_secs).max(0.0));
                if let (Some(jpb_src), Some(jpb_tgt)) = (src.realized_jpb(), tgt.realized_jpb())
                {
                    m.realized_benefit_j = Some((jpb_src - jpb_tgt) * tgt.realized_bytes);
                }
            }
        }
    }

    /// Per-residency calibration table (markdown-renderable).
    pub fn summary_table(&self) -> Table {
        let mut t = Table::new(
            "decision calibration",
            &["session", "host", "end", "predicted J/B", "realized J/B", "ratio"],
        );
        let cell = |v: Option<f64>| match v {
            Some(x) => format!("{x:.3e}"),
            None => "-".to_string(),
        };
        for r in &self.placements {
            t.push_row(vec![
                r.session.clone(),
                r.host.clone(),
                r.end.clone(),
                cell(r.predicted_jpb),
                cell(r.realized_jpb()),
                match r.error_ratio() {
                    Some(x) => format!("{x:.2}"),
                    None => "-".to_string(),
                },
            ]);
        }
        t
    }

    /// The whole ledger as one JSON object (placements, migrations,
    /// anomalies).
    pub fn to_json(&self) -> String {
        let placements: Vec<String> = self.placements.iter().map(|r| r.to_json()).collect();
        let migrations: Vec<String> = self.migrations.iter().map(|m| m.to_json()).collect();
        let anomalies: Vec<String> = self
            .anomalies
            .iter()
            .map(|a| {
                format!(
                    "{{\"session\":\"{}\",\"host\":\"{}\",\"t\":{},\"predicted_jpb\":{},\
                     \"realized_jpb\":{},\"ratio\":{}}}",
                    json::escape(&a.session),
                    json::escape(&a.host),
                    json::num(a.t_secs),
                    json::num(a.predicted_jpb),
                    json::num(a.realized_jpb),
                    json::num(a.ratio),
                )
            })
            .collect();
        format!(
            "{{\"kind\":\"greendt-calibration\",\"placements\":[{}],\"migrations\":[{}],\
             \"anomalies\":[{}]}}",
            placements.join(","),
            migrations.join(","),
            anomalies.join(",")
        )
    }
}

/// Jain's fairness index over an iterator of non-negative shares:
/// `(Σx)² / (n·Σx²)`, 1 for perfectly equal shares, `1/n` for one
/// share taking everything. `None` when no positive share exists.
pub fn jain_index(shares: impl Iterator<Item = f64>) -> Option<f64> {
    let mut sum = 0.0;
    let mut sum_sq = 0.0;
    let mut n = 0usize;
    for x in shares {
        if x > 0.0 {
            sum += x;
            sum_sq += x * x;
            n += 1;
        }
    }
    if n == 0 {
        return None;
    }
    Some((sum * sum) / (n as f64 * sum_sq))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(session: &str, predicted: Option<f64>, bytes: f64, joules: f64) -> CalibrationRecord {
        CalibrationRecord {
            session: session.to_string(),
            host: "h0".to_string(),
            end: "complete".to_string(),
            t0_secs: 0.0,
            t1_secs: 10.0,
            predicted_jpb: predicted,
            realized_bytes: bytes,
            realized_joules: joules,
        }
    }

    #[test]
    fn error_ratio_and_anomaly_flags() {
        let perfect = rec("a", Some(2e-8), 1e9, 20.0);
        assert_eq!(perfect.realized_jpb(), Some(2e-8));
        assert_eq!(perfect.error_ratio(), Some(1.0));
        assert_eq!(perfect.rel_error(), Some(0.0));
        assert!(!perfect.is_anomalous(4.0));

        let over = rec("b", Some(2e-8), 1e9, 100.0); // 5x the prediction
        assert!(over.is_anomalous(4.0));
        assert!(!over.is_anomalous(6.0));
        let under = rec("c", Some(2e-8), 1e9, 2.0); // 10x cheaper
        assert!(under.is_anomalous(4.0), "deviation is flagged in both directions");

        let unpredicted = rec("d", None, 1e9, 20.0);
        assert_eq!(unpredicted.error_ratio(), None);
        assert!(!unpredicted.is_anomalous(4.0));
        let empty = rec("e", Some(2e-8), 0.0, 0.0);
        assert_eq!(empty.realized_jpb(), None);
        assert!(!empty.is_anomalous(4.0));
    }

    #[test]
    fn migration_join_computes_realized_benefit() {
        let mut ledger = CalibrationLedger::default();
        // Source residency on `legacy`: 10 J over 1e9 B, preempted at 100 s.
        ledger.placements.push(CalibrationRecord {
            session: "s".into(),
            host: "legacy".into(),
            end: "preempt".into(),
            t0_secs: 0.0,
            t1_secs: 100.0,
            predicted_jpb: Some(1e-8),
            realized_bytes: 1e9,
            realized_joules: 10.0,
        });
        // Target residency: 4 J over 2e9 B, resumed 2 s late.
        ledger.placements.push(CalibrationRecord {
            session: "s".into(),
            host: "efficient".into(),
            end: "complete".into(),
            t0_secs: 107.0,
            t1_secs: 300.0,
            predicted_jpb: Some(2e-9),
            realized_bytes: 2e9,
            realized_joules: 4.0,
        });
        ledger.migrations.push(MigrationCalibration {
            session: "s".into(),
            from: "legacy".into(),
            to: "efficient".into(),
            t_secs: 100.0,
            resume_at_secs: 105.0,
            est_benefit_j: 12.0,
            est_cost_j: 3.0,
            realized_delay_s: None,
            realized_benefit_j: None,
        });
        ledger.join_migrations();
        let m = &ledger.migrations[0];
        assert_eq!(m.realized_delay_s, Some(2.0));
        // (1e-8 - 2e-9) * 2e9 = 16 J realized vs 12 J estimated.
        let realized = m.realized_benefit_j.expect("joined");
        assert!((realized - 16.0).abs() < 1e-9, "got {realized}");
        assert!((m.benefit_error_j().unwrap() - 4.0).abs() < 1e-9);
        assert_eq!(m.predicted_net_j(), 9.0);
    }

    #[test]
    fn unjoined_migration_stays_unrealized() {
        let mut ledger = CalibrationLedger::default();
        ledger.migrations.push(MigrationCalibration {
            session: "ghost".into(),
            from: "a".into(),
            to: "b".into(),
            t_secs: 10.0,
            resume_at_secs: 15.0,
            est_benefit_j: 1.0,
            est_cost_j: 0.5,
            realized_delay_s: None,
            realized_benefit_j: None,
        });
        ledger.join_migrations();
        assert_eq!(ledger.migrations[0].realized_benefit_j, None);
        assert_eq!(ledger.migrations[0].benefit_error_j(), None);
    }

    #[test]
    fn ledger_json_parses_and_sums() {
        let mut ledger = CalibrationLedger::default();
        ledger.placements.push(rec("a", Some(2e-8), 1e9, 20.0));
        ledger.placements.push(rec("b", None, 5e8, 7.5));
        ledger.anomalies.push(CalibrationAnomaly {
            session: "a".into(),
            host: "h0".into(),
            t_secs: 10.0,
            predicted_jpb: 2e-8,
            realized_jpb: 1e-7,
            ratio: 5.0,
        });
        assert_eq!(ledger.realized_joules(), 27.5);
        assert_eq!(ledger.realized_bytes(), 1.5e9);
        let doc = ledger.to_json();
        let v = crate::history::json::parse(&doc).expect("ledger JSON parses");
        assert_eq!(v.get("placements").and_then(|p| p.as_arr()).unwrap().len(), 2);
        assert_eq!(v.get("anomalies").and_then(|p| p.as_arr()).unwrap().len(), 1);
        let md = ledger.summary_table().to_markdown();
        assert!(md.contains("calibration"));
    }

    #[test]
    fn jain_index_matches_definition() {
        assert_eq!(jain_index([1.0, 1.0, 1.0, 1.0].into_iter()), Some(1.0));
        let skew = jain_index([1.0, 0.0, 0.0].into_iter()).unwrap();
        assert_eq!(skew, 1.0, "zero shares are ignored");
        let two = jain_index([3.0, 1.0].into_iter()).unwrap();
        assert!((two - 0.8).abs() < 1e-12);
        assert_eq!(jain_index(std::iter::empty()), None);
    }
}
