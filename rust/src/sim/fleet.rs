//! Fleet driver: N concurrent transfer sessions on one shared host.
//!
//! Each tenant brings its own dataset and tuning algorithm; the world
//! shares one client CPU package, one power budget and one bottleneck
//! link. Tenants arrive on a scripted schedule, tune their own channel
//! counts at their own timeouts, and depart when their transfer
//! completes. A [`FleetPolicy`] arbitrates the *host-level* knobs (active
//! cores, frequency, per-session channel budget) on aggregate telemetry;
//! per-session CPU governors are disabled while a policy is in charge.
//!
//! [`super::session::run_session`] is exactly this driver with one
//! tenant, no policy, and the session's own governor left enabled. The
//! multi-host dispatcher ([`super::dispatcher`]) drives several of these
//! worlds — one per host — in lockstep behind a placement policy; the
//! per-host driver state lives in the crate-internal `HostWorld` so both
//! entry points share one implementation.

use std::collections::{BTreeMap, BTreeSet};

use crate::config::experiment::{GovernorKind, TunerParams};
use crate::config::Testbed;
use crate::coordinator::fleet::{FleetPolicy, FleetPolicyKind};
use crate::coordinator::{Algorithm, AlgorithmKind};
use crate::cpusim::{CpuDemand, CpuState};
use crate::dataset::{Dataset, FileSpec};
use crate::history::{RunOutcome, RunRecord, TrajPoint, WorkloadFingerprint};
use crate::netsim::{BandwidthEvent, CrossTrafficConfig};
use crate::obs::calibrate::CalibrationRecord;
use crate::obs::trace::{AttrValue, TraceBuf, TraceRecord};
use crate::resilience::DeadLetter;
use crate::sim::{Simulation, TickStats, TuneCtx, MAX_APP_UTILIZATION};
use crate::transfer::TransferEngine;
use crate::units::{Bytes, Energy, Freq, Rate, SimDuration, SimTime};

use super::session::TimelinePoint;

/// One tenant: a dataset to move, an algorithm to tune it, an arrival
/// time on the shared host.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    /// Display name of the tenant (unique within a run by convention).
    pub name: String,
    /// The files this tenant has to move.
    pub dataset: Dataset,
    /// The tuning algorithm driving this tenant's transfer.
    pub algorithm: AlgorithmKind,
    /// When this session is admitted (simulated clock).
    pub arrive_at: SimTime,
}

impl TenantSpec {
    /// A tenant arriving at t = 0.
    pub fn new(name: impl Into<String>, dataset: Dataset, algorithm: AlgorithmKind) -> Self {
        TenantSpec { name: name.into(), dataset, algorithm, arrive_at: SimTime::ZERO }
    }

    /// Set the arrival (admission) time.
    pub fn arriving_at(mut self, at: SimTime) -> Self {
        self.arrive_at = at;
        self
    }
}

/// Everything needed to run one multi-tenant world.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// The shared host + WAN path everyone runs on.
    pub testbed: Testbed,
    /// The sessions to serve, with their scripted arrival times.
    pub tenants: Vec<TenantSpec>,
    /// Host-level arbitration. `None` leaves the host knobs to the
    /// tenants' own governors (the single-session compatibility mode).
    pub policy: Option<FleetPolicyKind>,
    /// Tuner knobs shared by every tenant's algorithm.
    pub params: TunerParams,
    /// Arbitration cadence of the fleet policy.
    pub fleet_interval: SimDuration,
    /// RNG seed (background traffic noise).
    pub seed: u64,
    /// Simulation tick length.
    pub tick: SimDuration,
    /// Abort the run after this much simulated time.
    pub max_sim_time: SimDuration,
    /// Record a per-timeout timeline for every tenant (costs memory).
    pub record_timeline: bool,
    /// Scripted background-traffic events (failure injection).
    pub bandwidth_events: Vec<BandwidthEvent>,
    /// GreenDT extension: Algorithm-3 scaling on the *server* too.
    pub server_scaling: bool,
    /// Drive the world with the naive per-tick reference stepper
    /// ([`Simulation::step_reference`]) instead of the epoch-cached fast
    /// path — the oracle the stepper-equivalence tests pin against, and
    /// the baseline `bench_hotpath` reports speedup over.
    pub reference_stepper: bool,
    /// Model the background cross traffic as a deterministic constant
    /// (plus any scripted events) instead of the noisy OU process.
    /// Between events such a background is frozen, which lets warm
    /// epochs batch ticks (`Simulation::warm_batch_until`) — the mode
    /// the large-scale paths and `bench_scale` run in. Results stay
    /// bit-identical across steppers and shard counts either way.
    pub constant_bg: bool,
    /// Seeded cross-traffic generators on the bottleneck (steady UDP
    /// floor + bursty TCP flows) — the contended-path scenarios. Mutually
    /// exclusive with [`Self::constant_bg`]: stochastic cross-traffic
    /// unfreezes the link, so warm-epoch batching cannot engage.
    pub cross_traffic: Option<CrossTrafficConfig>,
    /// Run every tenant's streams with AIMD competing-flow dynamics
    /// ([`crate::transfer::TransferEngine::set_aimd`]) instead of the
    /// default slow-start-then-hold FSM.
    pub aimd: bool,
}

impl FleetConfig {
    /// A fleet on `testbed` under `policy`, with no tenants yet.
    pub fn new(testbed: Testbed, policy: Option<FleetPolicyKind>) -> Self {
        FleetConfig {
            testbed,
            tenants: Vec::new(),
            policy,
            params: TunerParams::default(),
            fleet_interval: SimDuration::from_secs(3.0),
            seed: 42,
            tick: SimDuration::from_millis(100.0),
            max_sim_time: SimDuration::from_secs(14_400.0),
            record_timeline: false,
            bandwidth_events: Vec::new(),
            server_scaling: false,
            reference_stepper: false,
            constant_bg: false,
            cross_traffic: None,
            aimd: false,
        }
    }

    /// Attach seeded cross-traffic generators (contended-path runs).
    pub fn with_cross_traffic(mut self, cross: CrossTrafficConfig) -> Self {
        self.cross_traffic = Some(cross);
        self
    }

    /// Switch every tenant's streams to AIMD competing-flow dynamics.
    pub fn with_aimd(mut self, on: bool) -> Self {
        self.aimd = on;
        self
    }

    /// Append one tenant.
    pub fn with_tenant(mut self, tenant: TenantSpec) -> Self {
        self.tenants.push(tenant);
        self
    }

    /// Replace the shared tuner parameters.
    pub fn with_params(mut self, params: TunerParams) -> Self {
        self.params = params;
        self
    }

    /// Set the RNG seed.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// What one tenant got out of the shared host.
#[derive(Debug, Clone)]
pub struct TenantOutcome {
    /// Tenant name (from its [`TenantSpec`]).
    pub name: String,
    /// Name of the tuning algorithm that drove the transfer.
    pub algorithm: String,
    /// Name of the host that served this tenant — the testbed name for a
    /// single-host fleet, the [`super::dispatcher::HostSpec`] name in a
    /// multi-host world.
    pub host: String,
    /// Whether the transfer finished before the time cap. False for a
    /// residency ended by preemption — the rebalancer re-admits the
    /// remaining bytes elsewhere, producing a second outcome under the
    /// same name.
    pub completed: bool,
    /// True when this residency ended because the fleet rebalancer
    /// preempted the session ([`crate::rebalance`]); `moved` then counts
    /// only the bytes delivered *here*, and the matching
    /// [`MigrationRecord`](crate::sim::MigrationRecord) names the target
    /// host serving the rest.
    pub preempted: bool,
    /// When the session was admitted.
    pub arrived_at: SimTime,
    /// When the transfer finished (`None` if it never did).
    pub finished_at: Option<SimTime>,
    /// Bytes actually moved.
    pub moved: Bytes,
    /// Average throughput over the tenant's residency on the host.
    pub avg_throughput: Rate,
    /// Time the tenant spent on the host (until it finished, or until the
    /// run's time cap for an unfinished tenant).
    pub residency: SimDuration,
    /// Host instrument energy attributed to this tenant: its share of
    /// every tick's draw while resident, weighted by bytes moved (ticks
    /// where nothing moved split evenly among resident tenants). Ticks
    /// with *no* resident session are host idle overhead attributed to
    /// nobody, so the tenant shares sum to the host bill only when the
    /// arrival schedule leaves no gaps.
    pub attributed_energy: Energy,
    /// Client package (RAPL) energy attributed to this tenant.
    pub attributed_package_energy: Energy,
    /// Most channels the tenant ever had open.
    pub peak_channels: u32,
    /// Per-timeout timeline (empty unless recording was requested).
    pub timeline: Vec<TimelinePoint>,
}

/// Per-host totals of a fleet run — one entry per host in
/// [`FleetOutcome::hosts`]. A single-host fleet has exactly one; the
/// multi-host dispatcher one per [`super::dispatcher::HostSpec`].
#[derive(Debug, Clone)]
pub struct HostBreakdown {
    /// Host name (testbed name for single-host runs).
    pub host: String,
    /// Name of the testbed this host models.
    pub testbed: String,
    /// Sessions this host admitted over the run.
    pub tenants_served: u32,
    /// Bytes moved through this host.
    pub moved: Bytes,
    /// Client energy per the testbed's instrument (RAPL or wall).
    pub client_energy: Energy,
    /// Client package (RAPL) energy.
    pub client_package_energy: Energy,
    /// Server package energy.
    pub server_energy: Energy,
    /// Client active-core count when the run ended.
    pub final_active_cores: u32,
    /// Client frequency when the run ended.
    pub final_freq: Freq,
}

/// Jain's fairness index over a set of allocations: `(Σx)² / (n · Σx²)`.
///
/// 1.0 means perfectly equal shares; `1/n` means one participant got
/// everything. Degenerate inputs (no participants, or all-zero shares)
/// report 1.0 — nothing was shared unfairly.
pub fn jain_index<I: IntoIterator<Item = f64>>(xs: I) -> f64 {
    let (mut n, mut sum, mut sum_sq) = (0u32, 0.0f64, 0.0f64);
    for x in xs {
        n += 1;
        sum += x;
        sum_sq += x * x;
    }
    if n == 0 || sum_sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (n as f64 * sum_sq)
}

/// What the whole fleet run produced.
#[derive(Debug, Clone)]
pub struct FleetOutcome {
    /// Name of the arbitration policy (and, in multi-host runs, the
    /// placement policy) that governed the run.
    pub policy: String,
    /// Per-tenant outcomes.
    pub tenants: Vec<TenantOutcome>,
    /// True when every tenant finished before the time cap.
    pub completed: bool,
    /// Makespan of the whole run.
    pub duration: SimDuration,
    /// Total bytes moved by all tenants.
    pub moved: Bytes,
    /// Host client energy per the testbed's instrument (RAPL or wall);
    /// summed over hosts in multi-host runs.
    pub client_energy: Energy,
    /// Client package (RAPL) energy, summed over hosts.
    pub client_package_energy: Energy,
    /// Server package energy, summed over hosts.
    pub server_energy: Energy,
    /// Client active cores at the end of the run (host 0 in multi-host
    /// runs; see [`Self::hosts`] for the rest).
    pub final_active_cores: u32,
    /// Client frequency at the end of the run (host 0 in multi-host runs).
    pub final_freq: Freq,
    /// Per-host breakdowns — one entry for a single-host fleet, one per
    /// host behind the dispatcher.
    pub hosts: Vec<HostBreakdown>,
    /// One history record per ended residency that moved bytes (see
    /// [`crate::history::RunRecord`]) — what `--record-history` appends
    /// to the store. Always populated; persisting is the caller's choice.
    pub run_records: Vec<RunRecord>,
    /// Sessions quarantined by the resilience pipeline (retry budget
    /// exhausted, or lost to a fault with recovery off). Always empty
    /// for single-host [`run_fleet`] runs and for dispatcher runs
    /// without faults — a first-class outcome, not a log line, so
    /// callers cannot mistake a quarantined fleet for a finished one
    /// ([`Self::completed`] is false while any session sits here).
    pub dead_letters: Vec<DeadLetter>,
    /// Dead letters dropped because the quarantine was full — non-zero
    /// means [`Self::dead_letters`] is an undercount.
    pub dead_letter_overflow: u64,
}

impl FleetOutcome {
    /// Host energy divided by tenant count — the fleet-level figure of
    /// merit (energy bill per served session).
    pub fn energy_per_tenant(&self) -> Energy {
        Energy::from_joules(
            self.client_energy.as_joules() / self.tenants.len().max(1) as f64,
        )
    }

    /// Jain fairness index over per-tenant goodput (average throughput of
    /// every tenant that was admitted). A migrated session appears once
    /// per residency in [`Self::tenants`]; its residencies are aggregated
    /// by name here, so the index measures per-*session* goodput, not
    /// per-residency. 1.0 = perfectly fair.
    pub fn jain_fairness(&self) -> f64 {
        let mut agg: std::collections::BTreeMap<&str, (f64, f64)> =
            std::collections::BTreeMap::new();
        for t in &self.tenants {
            if t.residency > SimDuration::ZERO {
                let e = agg.entry(t.name.as_str()).or_insert((0.0, 0.0));
                e.0 += t.moved.as_f64();
                e.1 += t.residency.as_secs();
            }
        }
        jain_index(agg.values().filter(|(_, s)| *s > 0.0).map(|(b, s)| b / s))
    }
}

/// Per-tenant runtime state the driver tracks outside the simulation.
struct TenantRun {
    algo: Box<dyn Algorithm>,
    slot: usize,
    init_channels: u32,
    admitted: bool,
    finished_at: Option<SimTime>,
    /// Absolute time (seconds) of the next tuning timeout.
    next_timeout: f64,
    timeout: f64,
    peak_channels: u32,
    timeline: Vec<TimelinePoint>,
    /// In fleet mode the policy owns the real host CPU; the tenant's
    /// governor actuates this per-tenant shadow setting instead, so even
    /// baselines with built-in OS governors cannot fight the policy.
    shadow_cpu: CpuState,
    /// Sessions already admitted and unfinished when this one was
    /// admitted — the history record's contention level.
    contention: u32,
    /// Channels in effect at the last tuning/arbitration event (the
    /// converged concurrency a warm start should reproduce; the engine's
    /// own count collapses once the transfer drains).
    last_channels: u32,
    /// Host client cores/P-state at departure (the settled operating
    /// point recorded into history).
    settled_cores: u32,
    settled_pstate: u32,
    /// True when the residency ended by rebalancer preemption rather than
    /// completion (`finished_at` is then the preemption instant).
    preempted: bool,
    /// How a residency that ended abnormally ended (set by
    /// [`HostWorld::mark_session_failed`] after a fault preemption or a
    /// dead-lettering): overrides the outcome `finish` would otherwise
    /// derive, so history records the failure instead of censoring it.
    failure: Option<RunOutcome>,
    /// The dispatcher's model-side marginal J/B score for the admitting
    /// host at admission time (`None` on single-host fleets, which have
    /// no placement step) — recorded into history so learned placement
    /// can blend scale-consistent terms.
    admission_marginal_jpb: Option<f64>,
}

/// The slice of a [`TenantSpec`] the driver still needs after
/// `init_tenant` has consumed the dataset: keeping the full spec alive
/// would pin every session's generated file list in memory for the whole
/// run (thousands of sessions in open workloads). The workload
/// fingerprint is taken here, at admission-record time, precisely so the
/// file list can be dropped.
struct TenantMeta {
    name: String,
    arrive_at: SimTime,
    fingerprint: WorkloadFingerprint,
    algo_id: &'static str,
    /// The full algorithm kind, kept so a preempted session can be
    /// re-initialized verbatim on its migration target.
    kind: AlgorithmKind,
}

/// Per-host trace state ([`HostWorld`]'s side of the ISSUE-9 tracer).
/// Lives entirely at segment boundaries: every emission happens from the
/// driver-event methods (`admissions_due`, `post_segment`, `preempt`),
/// never inside the tick loop, so the record stream is a pure function
/// of this host's deterministic event order — shard-count invariant by
/// construction.
struct HostTrace {
    /// This host's record buffer (track = host index + 1).
    buf: TraceBuf,
    /// Session-root span ids (allocated on the dispatcher's track 0 by
    /// its collector, handed over via [`HostWorld::trace_root`]).
    roots: BTreeMap<String, u64>,
    /// Open residency spans by tenant index: ids are pre-allocated at
    /// admission so child records can reference them, the span record
    /// itself is emitted at close (departure, preemption or time cap).
    open: BTreeMap<usize, OpenResidency>,
}

/// Per-host calibration state ([`HostWorld`]'s side of the ISSUE-10
/// decision calibration ledger). Like [`HostTrace`], it only acts at
/// segment-boundary events — the same three residency-close sites the
/// tracer uses — so the record stream is shard-count invariant by
/// construction, and it reads bytes/joules with the identical
/// expressions [`HostWorld::finish`] bills [`TenantOutcome`]s with, so
/// the ledger reconciles with the outcome to the bit.
struct HostCalib {
    /// Closed-residency records awaiting collection.
    records: Vec<CalibrationRecord>,
    /// Tenant indices whose residency already produced a record (one
    /// record per residency, whichever close site fires first).
    closed: BTreeSet<usize>,
}

/// One open residency span (see [`HostTrace::open`]).
struct OpenResidency {
    /// Pre-allocated id of the `admit` span.
    span: u64,
    /// Admission instant, seconds.
    t0: f64,
    /// Open slow-start phase `(pre-allocated id, t0)`; closed at the
    /// first tuning timeout where the FSM has left slow start.
    slow_start: Option<(u64, f64)>,
}

/// Install the policy's per-session channel budget on one tenant's
/// engine: future `set_num_channels` calls clamp to it (no churn), and a
/// count already above the new budget shrinks once now.
fn apply_cap(sim: &mut Simulation, slot: usize, cap: u32) {
    let engine = &mut sim.slot_mut(slot).engine;
    engine.set_channel_cap(Some(cap));
    if engine.num_channels() > cap {
        engine.update_weights();
        engine.set_num_channels(cap);
    }
}

/// One host's complete driver state: the simulation plus everything the
/// fleet loop tracks around it (tenants, tuning deadlines, the
/// arbitration cadence and the active channel cap).
///
/// [`run_fleet`] drives exactly one of these; the multi-host dispatcher
/// ([`super::dispatcher::run_dispatcher`]) drives one per host in
/// lockstep. The methods are the phases of the original single-host loop,
/// split so both drivers share one implementation: `admissions_due` →
/// `sample_peaks` → (`internal_horizon` + `step_once` inner loop) →
/// `post_segment`, then `finish`.
pub(crate) struct HostWorld {
    name: String,
    testbed: Testbed,
    pub(crate) sim: Simulation,
    specs: Vec<TenantMeta>,
    tenants: Vec<TenantRun>,
    policy: Option<Box<dyn FleetPolicy>>,
    params: TunerParams,
    record_timeline: bool,
    reference_stepper: bool,
    /// Every engine on this host runs AIMD competing-flow dynamics
    /// (applied to pre-registered tenants and dispatcher placements
    /// alike).
    aimd: bool,
    fleet_step: f64,
    next_fleet: f64,
    channel_cap: Option<u32>,
    /// Segment-boundary tracer state; `None` (the default) keeps every
    /// hook a no-op so untraced runs take the exact code path they
    /// always did.
    trace: Option<HostTrace>,
    /// Decision-calibration state; same `Option` discipline as `trace`
    /// (the dispatcher enables it whenever any observability is on).
    calib: Option<HostCalib>,
}

impl HostWorld {
    /// Assemble a world with `specs` pre-registered (engines parked until
    /// their arrival time). `policy_kind` must be `Some` when `specs` is
    /// empty: without tenants there is no Algorithm-1 plan to take the
    /// initial host CPU setting from.
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build(
        name: impl Into<String>,
        testbed: &Testbed,
        specs: &[TenantSpec],
        policy_kind: Option<FleetPolicyKind>,
        params: TunerParams,
        fleet_interval: SimDuration,
        tick: SimDuration,
        seed: u64,
        bandwidth_events: Vec<BandwidthEvent>,
        server_scaling: bool,
        record_timeline: bool,
        reference_stepper: bool,
        constant_bg: bool,
        cross_traffic: Option<CrossTrafficConfig>,
        aimd: bool,
    ) -> HostWorld {
        let policy: Option<Box<dyn FleetPolicy>> = policy_kind.map(|kind| kind.build(&params));

        // In fleet mode the policy owns the host CPU: tenant governors are
        // replaced by the null governor so they cannot fight over the
        // package.
        let mut params = params;
        if policy.is_some() {
            params.governor = GovernorKind::None;
        }

        // Initialize every pre-registered tenant's algorithm and engine up
        // front (Alg. 1 runs at submission time); engines stay parked
        // until admission.
        let mut tenants: Vec<TenantRun> = Vec::with_capacity(specs.len());
        let mut engines: Vec<TransferEngine> = Vec::with_capacity(specs.len());
        let mut first_cpu: Option<CpuState> = None;
        for spec in specs {
            let (run, engine, cpu) = init_tenant(spec, params, testbed);
            if first_cpu.is_none() {
                first_cpu = Some(cpu);
            }
            tenants.push(run);
            engines.push(engine);
        }

        // The host CPU starts where the policy (or, without one, the first
        // tenant's Algorithm-1 plan) says.
        let client = match &policy {
            Some(p) => p.initial_cpu(&testbed.client_cpu),
            None => first_cpu.expect("a fleet without a policy needs at least one tenant"),
        };
        let mut sim = if let Some(cross) = cross_traffic {
            // The CLI rejects this pair with a proper error; a library
            // caller mixing them gets a loud failure instead of silently
            // losing the constant (batchable) background.
            assert!(
                !constant_bg,
                "constant_bg and cross_traffic are mutually exclusive: \
                 stochastic cross-traffic unfreezes the link"
            );
            Simulation::empty_with_cross_traffic(
                testbed,
                client,
                tick,
                seed,
                bandwidth_events,
                cross,
            )
        } else if constant_bg {
            Simulation::empty_constant_bg(testbed, client, tick, seed, bandwidth_events)
        } else {
            Simulation::empty(testbed, client, tick, seed, bandwidth_events)
        };
        sim.host.server_autoscale = server_scaling;
        for (t, mut engine) in tenants.iter_mut().zip(engines) {
            engine.set_aimd(aimd);
            t.slot = sim.add_slot(engine);
        }

        // Arbitration cadence, floored at one tick so a degenerate config
        // cannot stall the catch-up loop.
        let fleet_step = fleet_interval.as_secs().max(tick.as_secs()).max(1e-3);

        HostWorld {
            name: name.into(),
            testbed: testbed.clone(),
            sim,
            specs: specs.iter().map(TenantMeta::of).collect(),
            tenants,
            policy,
            params,
            record_timeline,
            reference_stepper,
            aimd,
            fleet_step,
            next_fleet: fleet_step,
            channel_cap: None,
            trace: None,
            calib: None,
        }
    }

    /// Turn on segment-boundary tracing for this world, emitting on
    /// `track` (the dispatcher passes host index + 1; track 0 is the
    /// collector's).
    pub(crate) fn enable_trace(&mut self, track: u64) {
        self.trace = Some(HostTrace {
            buf: TraceBuf::new(track),
            roots: BTreeMap::new(),
            open: BTreeMap::new(),
        });
    }

    /// Hand this world the collector-allocated root span id for
    /// `session`, so residency spans opened here parent onto it. The
    /// dispatcher calls this right after [`Self::register_arrival`].
    pub(crate) fn trace_root(&mut self, session: &str, root: u64) {
        if let Some(tr) = self.trace.as_mut() {
            tr.roots.insert(session.to_string(), root);
        }
    }

    /// Drain this world's buffered trace records (the dispatcher merges
    /// per-host buffers in host-index order at every segment boundary).
    pub(crate) fn take_trace(&mut self) -> Vec<TraceRecord> {
        self.trace.as_mut().map(|tr| tr.buf.drain()).unwrap_or_default()
    }

    /// Close every still-open residency span at the current clock with
    /// `end="timecap"` — called once by the dispatcher before `finish`
    /// so sessions cut off by the run's time cap still serialize their
    /// byte/joule attribution.
    pub(crate) fn finalize_trace(&mut self) {
        let open: Vec<usize> = match &self.trace {
            Some(tr) => tr.open.keys().copied().collect(),
            None => return,
        };
        for tenant in open {
            self.trace_close_residency(tenant, "timecap");
        }
    }

    /// Turn on decision calibration for this world: every residency
    /// close will join the admission-time predicted J/B against the
    /// realized bytes/joules.
    pub(crate) fn enable_calibration(&mut self) {
        self.calib = Some(HostCalib { records: Vec::new(), closed: BTreeSet::new() });
    }

    /// Drain this world's buffered calibration records (the dispatcher
    /// collects per-host buffers in host-index order at every segment
    /// boundary, mirroring [`Self::take_trace`]).
    pub(crate) fn take_calibration(&mut self) -> Vec<CalibrationRecord> {
        self.calib.as_mut().map(|c| std::mem::take(&mut c.records)).unwrap_or_default()
    }

    /// Close every still-open residency's calibration record with
    /// `end="timecap"` — the calibration sibling of
    /// [`Self::finalize_trace`], called once by the dispatcher before
    /// `finish`.
    pub(crate) fn finalize_calibration(&mut self) {
        let pending: Vec<usize> = match &self.calib {
            Some(cal) => (0..self.tenants.len())
                .filter(|i| self.tenants[*i].admitted && !cal.closed.contains(i))
                .collect(),
            None => return,
        };
        for tenant in pending {
            self.calib_close_residency(tenant, "timecap");
        }
    }

    /// Record one residency's calibration join, ending now. Bytes and
    /// joules are read with the *identical* expressions [`Self::finish`]
    /// uses for [`TenantOutcome`] (and [`Self::trace_close_residency`]
    /// uses for the `admit` span), so the ledger's realized side
    /// bit-matches both. Fires at the same three sites as the trace
    /// close (`complete`, `preempt`, `timecap`); the `closed` set makes
    /// it idempotent per tenant.
    fn calib_close_residency(&mut self, tenant: usize, end: &str) {
        match self.calib.as_mut() {
            Some(cal) if cal.closed.insert(tenant) => {}
            _ => return,
        }
        let t = &self.tenants[tenant];
        let slot = self.sim.slot(t.slot);
        let engine = &slot.engine;
        let moved = engine.total().saturating_sub(engine.remaining());
        let record = CalibrationRecord {
            session: self.specs[tenant].name.clone(),
            host: self.name.clone(),
            end: end.to_string(),
            t0_secs: self.specs[tenant].arrive_at.as_secs(),
            t1_secs: self.sim.now.as_secs(),
            predicted_jpb: t.admission_marginal_jpb,
            realized_bytes: moved.as_f64(),
            realized_joules: slot.attributed_energy().as_joules(),
        };
        if let Some(cal) = self.calib.as_mut() {
            cal.records.push(record);
        }
    }

    /// Open the residency (`admit`) span for a tenant admitted *now*:
    /// the span id is pre-allocated so children can reference it, the
    /// record itself is emitted at close with the final byte/joule
    /// attribution. A session admitted in slow start also opens its
    /// `slow_start` phase span.
    fn trace_open_residency(&mut self, tenant: usize, now: f64) {
        let in_slow_start = self.tenants[tenant].algo.fsm_label() == "slow-start";
        let Some(tr) = self.trace.as_mut() else { return };
        let span = tr.buf.next_id();
        let slow_start = in_slow_start.then(|| (tr.buf.next_id(), now));
        tr.open.insert(tenant, OpenResidency { span, t0: now, slow_start });
    }

    /// Emit the `admit` residency span for one tenant, ending now. The
    /// byte/joule attributes are read with the *identical* expressions
    /// [`Self::finish`] uses for [`TenantOutcome`] — that is what makes
    /// the trace reconcile exactly with [`FleetOutcome`]. `end` is one
    /// of `complete`, `preempt`, `timecap`.
    fn trace_close_residency(&mut self, tenant: usize, end: &str) {
        let Some(tr) = self.trace.as_mut() else { return };
        let Some(open) = tr.open.remove(&tenant) else { return };
        let t = &self.tenants[tenant];
        let slot = self.sim.slot(t.slot);
        let engine = &slot.engine;
        let moved = engine.total().saturating_sub(engine.remaining());
        let now = self.sim.now.as_secs();
        let session = &self.specs[tenant].name;
        let root = tr.roots.get(session).copied();
        if let Some((ss, ss_t0)) = open.slow_start {
            tr.buf.span(
                Some(ss),
                "slow_start",
                ss_t0,
                now,
                Some(session),
                Some(&self.name),
                Some(open.span),
                Vec::new(),
            );
        }
        tr.buf.span(
            Some(open.span),
            "admit",
            open.t0,
            now,
            Some(session),
            Some(&self.name),
            root,
            vec![
                ("end", end.into()),
                ("moved_bytes", AttrValue::F64(moved.as_f64())),
                ("attributed_j", AttrValue::F64(slot.attributed_energy().as_joules())),
                (
                    "attributed_pkg_j",
                    AttrValue::F64(slot.attributed_package_energy().as_joules()),
                ),
                ("peak_channels", t.peak_channels.into()),
            ],
        );
    }

    /// Emit one `tune` decision event (and close the tenant's
    /// `slow_start` phase at the first timeout past it).
    fn trace_tune(&mut self, tenant: usize, ch_before: u32, throughput_bps: f64, power_w: f64) {
        let fsm = self.tenants[tenant].algo.fsm_label();
        let ch_after = self.tenants[tenant].last_channels;
        let now = self.sim.now.as_secs();
        let Some(tr) = self.trace.as_mut() else { return };
        let session = &self.specs[tenant].name;
        let parent = tr.open.get(&tenant).map(|o| o.span);
        tr.buf.event(
            "tune",
            now,
            Some(session),
            Some(&self.name),
            parent,
            vec![
                ("fsm", fsm.into()),
                ("channels_before", ch_before.into()),
                ("channels", ch_after.into()),
                ("throughput_bps", AttrValue::F64(throughput_bps)),
                ("power_w", AttrValue::F64(power_w)),
                ("halved", (ch_after < ch_before).into()),
            ],
        );
        if fsm != "slow-start" {
            if let Some(o) = tr.open.get_mut(&tenant) {
                if let Some((ss, ss_t0)) = o.slow_start.take() {
                    let span = o.span;
                    tr.buf.span(
                        Some(ss),
                        "slow_start",
                        ss_t0,
                        now,
                        Some(session),
                        Some(&self.name),
                        Some(span),
                        Vec::new(),
                    );
                }
            }
        }
    }

    /// Emit the departure pair for a tenant that completed now: the
    /// closed `admit` span plus a `complete` instant under it.
    fn trace_complete(&mut self, tenant: usize) {
        let parent = self.trace.as_ref().and_then(|tr| tr.open.get(&tenant).map(|o| o.span));
        self.trace_close_residency(tenant, "complete");
        let now = self.sim.now.as_secs();
        let Some(tr) = self.trace.as_mut() else { return };
        let session = &self.specs[tenant].name;
        tr.buf.event("complete", now, Some(session), Some(&self.name), parent, Vec::new());
    }

    /// Register a session that arrives *now* (a dispatcher placement): its
    /// algorithm initializes at the current clock and `admissions_due`
    /// will admit it before the next tick. `fingerprint` reuses a
    /// fingerprint the dispatcher already computed for placement scoring
    /// (fingerprinting walks the whole file list); `None` computes it
    /// here.
    pub(crate) fn register_arrival(
        &mut self,
        mut spec: TenantSpec,
        fingerprint: Option<WorkloadFingerprint>,
        admission_marginal_jpb: Option<f64>,
    ) {
        spec.arrive_at = self.sim.now;
        let (mut run, mut engine, _cpu) = init_tenant(&spec, self.params, &self.testbed);
        engine.set_aimd(self.aimd);
        run.slot = self.sim.add_slot(engine);
        run.admission_marginal_jpb = admission_marginal_jpb.filter(|m| m.is_finite());
        self.tenants.push(run);
        // Drop the dataset: only the name, arrival instant and workload
        // fingerprint are needed from here on.
        self.specs.push(TenantMeta {
            fingerprint: fingerprint.unwrap_or_else(|| WorkloadFingerprint::of(&spec.dataset)),
            algo_id: spec.algorithm.id(),
            kind: spec.algorithm,
            name: spec.name,
            arrive_at: spec.arrive_at,
        });
    }

    /// The testbed this host models.
    pub(crate) fn testbed(&self) -> &Testbed {
        &self.testbed
    }

    /// Admissions due now (t=0 tenants are admitted before the first
    /// tick; channels open cold, exactly like a fresh session).
    pub(crate) fn admissions_due(&mut self) {
        let now = self.sim.now.as_secs();
        // Contention as the history record defines it: sessions already
        // admitted and unfinished when this one joins. Simultaneous
        // admissions in this call count each other in admission order.
        let mut active =
            self.tenants.iter().filter(|t| t.admitted && t.finished_at.is_none()).count() as u32;
        for i in 0..self.tenants.len() {
            let t = &mut self.tenants[i];
            if !t.admitted && self.specs[i].arrive_at.as_secs() <= now + 1e-9 {
                t.admitted = true;
                t.contention = active;
                active += 1;
                self.sim.activate_slot(t.slot);
                let engine = &mut self.sim.slot_mut(t.slot).engine;
                engine.set_channel_cap(self.channel_cap);
                engine.update_weights();
                engine.set_num_channels(t.init_channels);
                t.peak_channels = engine.num_channels();
                t.last_channels = engine.num_channels();
                self.trace_open_residency(i, now);
            }
        }
    }

    /// Channel counts only move at the driver-level events that bound a
    /// segment (tuning, arbitration, admission) or drop to zero on
    /// completion, so sampling the peak once per segment equals the old
    /// per-tick max.
    pub(crate) fn sample_peaks(&mut self) {
        for t in self.tenants.iter_mut() {
            if t.admitted && t.finished_at.is_none() {
                t.peak_channels =
                    t.peak_channels.max(self.sim.slot(t.slot).engine.num_channels());
            }
        }
    }

    /// Event horizon: the earliest instant any driver-level event on THIS
    /// host can fire — the earliest pending admission, tuning timeout or
    /// fleet arbitration, bounded by `cap_secs` (the run's time cap). The
    /// dispatcher takes the min across hosts plus its own arrival times.
    pub(crate) fn internal_horizon(&self, cap_secs: f64) -> f64 {
        let mut horizon = cap_secs;
        for (t, spec) in self.tenants.iter().zip(&self.specs) {
            if !t.admitted {
                horizon = horizon.min(spec.arrive_at.as_secs());
            } else if t.finished_at.is_none() {
                horizon = horizon.min(t.next_timeout);
            }
        }
        if self.policy.is_some() {
            horizon = horizon.min(self.next_fleet);
        }
        horizon
    }

    /// Advance this host's simulation by one tick.
    pub(crate) fn step_once(&mut self) -> TickStats {
        if self.reference_stepper {
            self.sim.step_reference()
        } else {
            self.sim.step()
        }
    }

    /// Warm-epoch batching inside a segment: after the driver's slow
    /// tick has confirmed no break fired, burn the remaining pure warm
    /// ticks up to (strictly before) the segment horizon in one call,
    /// skipping the per-tick break re-checks. Returns the last batched
    /// tick's stats when any ticks ran. No-op on the reference stepper —
    /// and a no-op whenever the epoch is cold or the background is not
    /// frozen, so default (noisy-link) worlds are entirely unaffected.
    pub(crate) fn warm_batch(&mut self, horizon: f64, cap_secs: f64) -> Option<TickStats> {
        if self.reference_stepper {
            return None;
        }
        let (ticks, stats) = self.sim.warm_batch_until(horizon.min(cap_secs));
        if ticks == 0 {
            None
        } else {
            Some(stats)
        }
    }

    /// Advance exactly `ticks` ticks, warm-batching where the epoch
    /// allows and falling back to single steps elsewhere. The sharded
    /// dispatcher calls this only for spans it has proven free of driver
    /// events, horizon breaks and completions, so per-world state is
    /// bit-identical to `ticks` bare [`Self::step_once`] calls.
    pub(crate) fn advance_ticks(&mut self, ticks: u64) {
        let mut left = ticks;
        while left > 0 {
            if !self.reference_stepper {
                let (burned, _) = self.sim.warm_batch_ticks(left);
                left -= burned;
                if left == 0 {
                    break;
                }
            }
            self.step_once();
            left -= 1;
        }
    }

    /// Ticks this world can take before any session could possibly
    /// complete: one tick moves at most the link's full capacity times
    /// the tick length, so the least-remaining active session bounds the
    /// count from below (minus a two-tick margin for floating-point
    /// slack). Zero whenever a completion could be imminent — the
    /// sharded dispatcher then falls back to serial lockstep ticks,
    /// where the per-tick completion check lives.
    pub(crate) fn completion_bound_ticks(&self) -> u64 {
        let cap_bytes =
            self.testbed.link.capacity.as_bytes_per_sec() * self.sim.tick_len().as_secs();
        if cap_bytes <= 0.0 {
            return 0;
        }
        let mut bound = u64::MAX;
        for s in self.sim.slots() {
            if !s.is_active() {
                continue;
            }
            let ticks = (s.engine.remaining().as_f64() / cap_bytes).floor() as i64 - 2;
            bound = bound.min(ticks.max(0) as u64);
        }
        bound
    }

    /// The driver-level events at a segment boundary, in the order the
    /// per-tick loop used to check them: per-tenant tuning timeouts, then
    /// host-level arbitration, then departures.
    pub(crate) fn post_segment(&mut self) {
        let fleet_managed = self.policy.is_some();

        // Per-tenant tuning timeouts. A tick that overshoots several
        // timeouts drains once and then advances `next_timeout` past the
        // clock, so long ticks cannot skew the tuning cadence.
        for i in 0..self.tenants.len() {
            let t = &mut self.tenants[i];
            if !t.admitted || t.finished_at.is_some() {
                continue;
            }
            if self.sim.now.as_secs() + 1e-9 >= t.next_timeout {
                let tel = self.sim.drain_telemetry_for(t.slot);
                let ch_before = tel.num_channels;
                if self.record_timeline {
                    t.timeline.push(TimelinePoint {
                        t_secs: tel.now.as_secs(),
                        fsm: t.algo.fsm_label(),
                        throughput: tel.avg_throughput,
                        channels: tel.num_channels,
                        active_cores: self.sim.host.client.active_cores(),
                        freq: self.sim.host.client.freq(),
                        cpu_load: tel.cpu_load,
                        power_w: tel.avg_power.as_watts(),
                    });
                }
                if fleet_managed {
                    // The policy owns the real host CPU: hand the tenant's
                    // governor a shadow setting it can harmlessly actuate.
                    let ctx = &mut TuneCtx {
                        engine: &mut self.sim.slot_mut(t.slot).engine,
                        client: &mut t.shadow_cpu,
                    };
                    t.algo.on_timeout(&tel, ctx);
                } else {
                    t.algo.on_timeout(&tel, &mut self.sim.tune_ctx(t.slot));
                }
                t.last_channels = self.sim.slot(t.slot).engine.num_channels().max(1);
                t.next_timeout += t.timeout;
                while self.sim.now.as_secs() + 1e-9 >= t.next_timeout {
                    t.next_timeout += t.timeout;
                }
                if self.trace.is_some() {
                    let throughput_bps = tel.avg_throughput.as_bytes_per_sec();
                    let power_w = tel.avg_power.as_watts();
                    self.trace_tune(i, ch_before, throughput_bps, power_w);
                }
            }
        }

        // Host-level arbitration at the fleet cadence.
        if let Some(p) = self.policy.as_mut() {
            if self.sim.now.as_secs() + 1e-9 >= self.next_fleet {
                let active = self.sim.active_sessions();
                let view = self.sim.host.drain_fleet_interval(self.sim.now, active);
                let directive = p.arbitrate(&view, &mut self.sim.host.client);
                self.channel_cap = directive.per_session_channel_cap;
                if let Some(total) = directive.weighted_channel_budget {
                    // Weighted split: each active session's slice of the
                    // total budget is proportional to its remaining
                    // bytes, so heavy tenants get the concurrency and
                    // near-done ones release it (ROADMAP "smarter
                    // arbitration"). Newly admitted sessions run under
                    // `channel_cap` (the equal-split fallback the policy
                    // also returns) until the next arbitration.
                    let idx: Vec<usize> = (0..self.tenants.len())
                        .filter(|&i| {
                            self.tenants[i].admitted && self.tenants[i].finished_at.is_none()
                        })
                        .collect();
                    let remaining: Vec<f64> =
                        idx.iter().map(|&i| self.tenant_remaining_bytes(i)).collect();
                    let caps = crate::coordinator::fleet::weighted_caps(total, &remaining);
                    for (&i, &cap) in idx.iter().zip(&caps) {
                        let slot = self.tenants[i].slot;
                        apply_cap(&mut self.sim, slot, cap);
                        self.tenants[i].last_channels =
                            self.sim.slot(slot).engine.num_channels().max(1);
                    }
                } else if let Some(cap) = self.channel_cap {
                    for t in self.tenants.iter_mut() {
                        if t.admitted && t.finished_at.is_none() {
                            apply_cap(&mut self.sim, t.slot, cap);
                            t.last_channels =
                                self.sim.slot(t.slot).engine.num_channels().max(1);
                        }
                    }
                }
                self.next_fleet += self.fleet_step;
                while self.sim.now.as_secs() + 1e-9 >= self.next_fleet {
                    self.next_fleet += self.fleet_step;
                }
            }
        }

        // Departures: a finished tenant releases its share of the host.
        for i in 0..self.tenants.len() {
            let t = &mut self.tenants[i];
            if t.admitted
                && t.finished_at.is_none()
                && self.sim.slot(t.slot).engine.is_done()
            {
                t.finished_at = Some(self.sim.now);
                // Freeze the settled operating point for the history
                // record: the host CPU setting the session departed under
                // plus the channel count it last ran with.
                t.settled_cores = self.sim.host.client.active_cores();
                t.settled_pstate = self.sim.host.client.freq_index() as u32;
                self.sim.deactivate_slot(t.slot);
                self.calib_close_residency(i, "complete");
                if self.trace.is_some() {
                    self.trace_complete(i);
                }
            }
        }
    }

    /// True once every registered session is finished with this host:
    /// its engine has moved all of its data, or its residency was ended
    /// (completion or preemption — a preempted engine keeps its remaining
    /// bytes, which now belong to another host's re-admission). Without
    /// preemptions this is exactly [`Simulation::is_done`].
    pub(crate) fn all_done(&self) -> bool {
        self.tenants
            .iter()
            .all(|t| t.finished_at.is_some() || self.sim.slot(t.slot).engine.is_done())
    }

    /// Name of the arbitration policy in charge ("none" without one).
    pub(crate) fn policy_name(&self) -> &'static str {
        match &self.policy {
            Some(p) => p.name(),
            None => "none",
        }
    }

    /// Current simulated time in seconds.
    pub(crate) fn now_secs(&self) -> f64 {
        self.sim.now.as_secs()
    }

    /// Sessions registered and unfinished — unlike
    /// [`Simulation::active_sessions`] this also counts sessions
    /// registered in the current segment that the next `admissions_due`
    /// call will activate. The dispatcher's occupancy view: simultaneous
    /// arrivals must each claim their slot immediately.
    pub(crate) fn occupancy(&self) -> u32 {
        self.tenants.iter().filter(|t| t.finished_at.is_none()).count() as u32
    }

    /// The sessions currently *running* here (admitted, activated,
    /// unfinished) as `(tenant index, name, remaining bytes)` — the
    /// rebalancer's per-host move candidates. Sessions registered this
    /// segment but not yet activated are excluded: they have not served a
    /// single tick, so "moving" them would just be a second placement
    /// decision.
    pub(crate) fn running_sessions(&self) -> Vec<(usize, String, f64)> {
        self.tenants
            .iter()
            .enumerate()
            .filter(|(_, t)| {
                t.admitted && t.finished_at.is_none() && self.sim.slot(t.slot).is_active()
            })
            .map(|(i, t)| {
                (i, self.specs[i].name.clone(), self.sim.slot(t.slot).engine.remaining().as_f64())
            })
            .collect()
    }

    /// Path round-trip time of this host's link, seconds (prices the
    /// migration slow-start re-ramp).
    pub(crate) fn link_rtt_s(&self) -> f64 {
        self.testbed.link.rtt.as_secs()
    }

    /// Remaining bytes of one tenant's engine (weighted-split input).
    fn tenant_remaining_bytes(&self, tenant: usize) -> f64 {
        let slot = self.tenants[tenant].slot;
        self.sim.slot(slot).engine.remaining().as_f64()
    }

    /// Preempt a running session for migration: end its residency *now*,
    /// freeze its partial-run accounting (bytes delivered here, settled
    /// operating point), drain its streams, and hand back everything the
    /// dispatcher needs to re-admit the remaining bytes elsewhere. The
    /// remaining bytes leave with the returned dataset — this host's
    /// engine keeps them only as inert bookkeeping (`all_done` treats the
    /// preempted tenant as departed).
    pub(crate) fn preempt(&mut self, tenant: usize) -> PreemptedSession {
        // Close the residency span (and its calibration record) first:
        // the byte/joule reads below are unaffected by the drain, and the
        // close must see the slot still resident.
        self.trace_close_residency(tenant, "preempt");
        self.calib_close_residency(tenant, "preempt");
        let now = self.sim.now;
        let t = &mut self.tenants[tenant];
        debug_assert!(
            t.admitted && t.finished_at.is_none(),
            "only running sessions can be preempted"
        );
        t.finished_at = Some(now);
        t.preempted = true;
        t.settled_cores = self.sim.host.client.active_cores();
        t.settled_pstate = self.sim.host.client.freq_index() as u32;
        let slot = t.slot;
        let engine = &mut self.sim.slot_mut(slot).engine;
        let moved = engine.total().saturating_sub(engine.remaining());
        let dataset = remaining_dataset(&self.specs[tenant].name, engine.partitions());
        engine.drain_channels();
        self.sim.deactivate_slot(slot);
        PreemptedSession {
            name: self.specs[tenant].name.clone(),
            algorithm: self.specs[tenant].kind,
            moved,
            remaining: dataset.total_size(),
            dataset,
        }
    }

    /// Record how an abnormally-ended residency ended (fault preemption,
    /// dead-lettering). Called by the dispatcher right after
    /// [`Self::preempt`]; `finish` then writes the failure outcome into
    /// the tenant's history record instead of skipping it.
    pub(crate) fn mark_session_failed(&mut self, tenant: usize, outcome: RunOutcome) {
        debug_assert!(!outcome.is_completed(), "failures only");
        self.tenants[tenant].failure = Some(outcome);
    }

    /// Total bytes every residency on this host has delivered so far —
    /// the monotone counter the health monitor differentiates to get
    /// per-segment delivered throughput. Slots are never reused, so the
    /// per-tenant sum cannot double count.
    pub(crate) fn moved_bytes(&self) -> f64 {
        self.tenants
            .iter()
            .map(|t| {
                let e = &self.sim.slot(t.slot).engine;
                e.total().saturating_sub(e.remaining()).as_f64()
            })
            .sum()
    }

    /// Analytic steady-state CPU demand estimate for `sessions` concurrent
    /// sessions on this host: aggregate goodput at the link's effective
    /// capacity (bottleneck minus mean background), bounded by the CPU
    /// ceiling at the maximum operating point, with each session running
    /// the knee-many streams the allocator favors. Requests are omitted —
    /// their cycle cost is negligible next to per-byte and per-stream
    /// work. Used by the dispatcher's placement scoring, never by the
    /// stepper itself.
    pub(crate) fn projected_demand(&self, sessions: u32) -> CpuDemand {
        if sessions == 0 {
            return CpuDemand::default();
        }
        let link = &self.testbed.link;
        let effective = link.capacity.as_bytes_per_sec() * (1.0 - self.testbed.bg_mean);
        let streams = link.knee_streams() * sessions as f64;
        let spec = &self.testbed.client_cpu;
        let cpu_cap = spec.achievable_bytes_per_sec(
            spec.num_cores,
            spec.max_freq(),
            0.0,
            streams,
            MAX_APP_UTILIZATION,
        );
        CpuDemand {
            bytes_per_sec: effective.min(cpu_cap),
            requests_per_sec: 0.0,
            open_streams: streams,
        }
    }

    /// Predicted whole-host instrument power (W) with `sessions`
    /// concurrent sessions, at the cheapest client operating point able to
    /// carry the projected demand.
    pub(crate) fn projected_power_w(&self, sessions: u32) -> f64 {
        self.sim
            .host
            .projected_instrument_power(&self.projected_demand(sessions))
            .as_watts()
    }

    /// Expected per-session goodput (bytes/s) with `sessions` sessions
    /// sharing the host.
    pub(crate) fn projected_session_bps(&self, sessions: u32) -> f64 {
        if sessions == 0 {
            0.0
        } else {
            self.projected_demand(sessions).bytes_per_sec / sessions as f64
        }
    }

    /// Tear the world down into per-tenant outcomes, this host's totals,
    /// and one history [`RunRecord`] per residency that moved bytes —
    /// completed or not, each tagged with its [`RunOutcome`] (the record
    /// hook behind `--record-history`; callers that don't persist them
    /// pay only their construction).
    pub(crate) fn finish(self) -> (Vec<TenantOutcome>, HostBreakdown, Vec<RunRecord>) {
        let HostWorld { name, testbed, sim, specs, tenants, .. } = self;
        let mut outcomes = Vec::with_capacity(tenants.len());
        let mut records = Vec::new();
        let mut moved_total = Bytes::ZERO;
        let mut served = 0u32;
        for (t, spec) in tenants.into_iter().zip(&specs) {
            let slot = sim.slot(t.slot);
            let moved = slot.engine.total().saturating_sub(slot.engine.remaining());
            moved_total += moved;
            if t.admitted {
                served += 1;
            }
            let end = t.finished_at.unwrap_or(sim.now);
            let residency = if t.admitted {
                end.since(slot.arrived_at())
            } else {
                SimDuration::ZERO
            };
            // Every residency that moved bytes leaves a history record —
            // including the ones that ended badly. Recording only the
            // completions (the pre-v3 behaviour) was survivorship bias:
            // a flaky host's disasters vanished from the log and only
            // its lucky runs trained the learner. The k-NN down-weights
            // non-completed outcomes rather than trusting them; a
            // rebalancer-preempted residency records as `Preempted` (its
            // resumed run on the target records separately), a
            // fault-preempted or quarantined one as whatever the
            // dispatcher marked, and a residency still unfinished at
            // the time cap as `Failed`.
            if t.admitted && !moved.is_zero() {
                let outcome = t.failure.unwrap_or(if t.finished_at.is_some() && !t.preempted {
                    RunOutcome::Completed
                } else if t.preempted {
                    RunOutcome::Preempted
                } else {
                    RunOutcome::Failed
                });
                records.push(run_record(
                    &t,
                    spec,
                    &testbed,
                    &name,
                    moved,
                    residency,
                    slot.attributed_energy(),
                    outcome,
                ));
            }
            outcomes.push(TenantOutcome {
                name: spec.name.clone(),
                algorithm: t.algo.name().to_string(),
                host: name.clone(),
                completed: t.finished_at.is_some() && !t.preempted,
                preempted: t.preempted,
                arrived_at: spec.arrive_at,
                finished_at: t.finished_at,
                moved,
                avg_throughput: Rate::average(moved, residency),
                residency,
                attributed_energy: slot.attributed_energy(),
                attributed_package_energy: slot.attributed_package_energy(),
                peak_channels: t.peak_channels,
                timeline: t.timeline,
            });
        }
        let breakdown = HostBreakdown {
            host: name,
            testbed: testbed.name.to_string(),
            tenants_served: served,
            moved: moved_total,
            client_energy: sim.client_energy(),
            client_package_energy: sim.host.client_rapl.total(),
            server_energy: sim.server_energy(),
            final_active_cores: sim.host.client.active_cores(),
            final_freq: sim.host.client.freq(),
        };
        (outcomes, breakdown, records)
    }
}

impl TenantMeta {
    /// Capture what the driver keeps of a spec (fingerprinting the
    /// dataset so the file list can be dropped).
    fn of(spec: &TenantSpec) -> TenantMeta {
        TenantMeta {
            name: spec.name.clone(),
            arrive_at: spec.arrive_at,
            fingerprint: WorkloadFingerprint::of(&spec.dataset),
            algo_id: spec.algorithm.id(),
            kind: spec.algorithm,
        }
    }
}

/// What [`HostWorld::preempt`] hands the dispatcher: everything needed to
/// re-admit the session's remaining bytes on another host.
pub(crate) struct PreemptedSession {
    /// Session name (unchanged across the move).
    pub(crate) name: String,
    /// The algorithm the session was admitted with, re-initialized
    /// verbatim on the target (Algorithm 1 re-plans, the FSM re-tunes).
    pub(crate) algorithm: AlgorithmKind,
    /// Bytes the session delivered on the source before preemption.
    pub(crate) moved: Bytes,
    /// Bytes the synthesized remaining dataset carries.
    pub(crate) remaining: Bytes,
    /// The remaining bytes as a dataset the target can admit.
    pub(crate) dataset: Dataset,
}

/// Synthesize the dataset a preempted session still owes: per unfinished
/// partition, the remaining bytes re-materialize as files of that band's
/// average size (plus one remainder file), so the target host's
/// Algorithm-1 partitioning sees the same size classes the source was
/// serving. Byte-exact up to f64 addition order: the file sizes sum to
/// the engine's remaining bytes, which is what byte conservation across
/// a migration means.
fn remaining_dataset(name: &str, parts: &[crate::transfer::PartitionProgress]) -> Dataset {
    let mut files = Vec::new();
    let mut id = 0u32;
    for p in parts {
        let left = p.remaining.as_f64();
        if left <= 0.0 {
            continue;
        }
        let chunk = p.avg_file_size.as_f64().max(1.0);
        let whole = (left / chunk).floor() as u64;
        if whole == 0 {
            files.push(FileSpec::new(id, Bytes::new(left)));
            id += 1;
            continue;
        }
        for _ in 0..whole {
            files.push(FileSpec::new(id, Bytes::new(chunk)));
            id += 1;
        }
        let rem = left - whole as f64 * chunk;
        if rem > 0.0 {
            files.push(FileSpec::new(id, Bytes::new(rem)));
            id += 1;
        }
    }
    Dataset::new(name.to_string(), files)
}

/// Assemble one ended residency's history record. The settled operating
/// point is the host CPU setting at departure plus the channel count the
/// session last tuned to; the trajectory is populated from the timeline
/// when one was recorded.
#[allow(clippy::too_many_arguments)]
fn run_record(
    t: &TenantRun,
    spec: &TenantMeta,
    testbed: &Testbed,
    host: &str,
    moved: Bytes,
    residency: SimDuration,
    attributed: Energy,
    outcome: RunOutcome,
) -> RunRecord {
    let ladder = &testbed.client_cpu.freq_levels;
    let traj = t
        .timeline
        .iter()
        .map(|p| TrajPoint {
            t_secs: p.t_secs,
            cores: p.active_cores,
            pstate: ladder.iter().position(|&f| f == p.freq).unwrap_or(0) as u32,
            channels: p.channels,
        })
        .collect();
    let moved_f = moved.as_f64();
    let joules = attributed.as_joules();
    RunRecord {
        session: spec.name.clone(),
        algorithm: spec.algo_id.to_string(),
        host: host.to_string(),
        testbed: testbed.name.to_string(),
        rtt_s: testbed.link.rtt.as_secs(),
        bandwidth_bps: testbed.link.capacity.as_bits_per_sec(),
        workload: spec.fingerprint,
        contention: t.contention,
        cores: t.settled_cores,
        pstate: t.settled_pstate,
        channels: t.last_channels,
        peak_channels: t.peak_channels,
        goodput_bps: Rate::average(moved, residency).as_bytes_per_sec(),
        joules,
        j_per_byte: if moved_f > 0.0 { joules / moved_f } else { 0.0 },
        moved_bytes: moved_f,
        duration_s: residency.as_secs(),
        completed: outcome.is_completed(),
        outcome,
        admission_marginal_jpb: t.admission_marginal_jpb,
        traj,
    }
}

/// Build one tenant's algorithm + engine from its spec (Algorithm 1 runs
/// at submission time). Returns the driver state, the parked engine, and
/// the plan's client CPU setting (the host's initial setting when no
/// fleet policy is in charge).
fn init_tenant(
    spec: &TenantSpec,
    params: TunerParams,
    testbed: &Testbed,
) -> (TenantRun, TransferEngine, CpuState) {
    let mut algo = spec.algorithm.build(params);
    let plan = algo.init(testbed, &spec.dataset);
    let mut engine = TransferEngine::with_knee(
        &plan.partitions,
        testbed.link.avg_win,
        testbed.link.knee_streams(),
    );
    if plan.handshake_rtts > 0.0 {
        for i in 0..plan.partitions.len() {
            engine.set_handshake_rtts(i, plan.handshake_rtts);
        }
    }
    engine.update_weights();
    // Floored so a degenerate timeout cannot stall the catch-up loop.
    let timeout = algo.timeout().as_secs().max(1e-3);
    let cpu = plan.client_cpu.clone();
    let run = TenantRun {
        algo,
        slot: 0, // assigned by the caller
        init_channels: plan.num_channels,
        admitted: false,
        finished_at: None,
        next_timeout: spec.arrive_at.as_secs() + timeout,
        timeout,
        peak_channels: 0,
        timeline: Vec::new(),
        shadow_cpu: plan.client_cpu,
        contention: 0,
        last_channels: plan.num_channels,
        settled_cores: cpu.active_cores(),
        settled_pstate: cpu.freq_index() as u32,
        preempted: false,
        failure: None,
        admission_marginal_jpb: None,
    };
    (run, engine, cpu)
}

/// Run a multi-tenant world to completion (or the time cap).
pub fn run_fleet(cfg: &FleetConfig) -> FleetOutcome {
    assert!(!cfg.tenants.is_empty(), "a fleet needs at least one tenant");

    let mut world = HostWorld::build(
        cfg.testbed.name,
        &cfg.testbed,
        &cfg.tenants,
        cfg.policy,
        cfg.params,
        cfg.fleet_interval,
        cfg.tick,
        cfg.seed,
        cfg.bandwidth_events.clone(),
        cfg.server_scaling,
        cfg.record_timeline,
        cfg.reference_stepper,
        cfg.constant_bg,
        cfg.cross_traffic,
        cfg.aimd,
    );
    let max = cfg.max_sim_time.as_secs();

    while !world.all_done() && world.now_secs() < max {
        world.admissions_due();
        world.sample_peaks();

        // Event horizon: between now and the earliest driver-level event
        // every tick is pure stepping, so run a tight inner loop that
        // skips the per-tick deadline re-checks the old driver made.
        // Completions end a segment early (the departure scan must run on
        // exactly the tick a tenant finishes, as it would per-tick). The
        // break comparison is the identical `now + 1e-9 >= deadline` the
        // per-tick scans make, so no event fires earlier or later than it
        // did pre-horizon.
        let horizon = world.internal_horizon(max);
        loop {
            let stats = world.step_once();
            if stats.session_completed
                || world.now_secs() + 1e-9 >= horizon
                || world.now_secs() >= max
            {
                break;
            }
            // Warm-epoch batching: the break checks just cleared, so burn
            // the remaining pure warm ticks of this segment in one call
            // (each bit-identical to a slow tick, the clock kept strictly
            // short of the horizon) and re-enter the slow loop for the
            // segment-ending ticks.
            if let Some(stats) = world.warm_batch(horizon, max) {
                if stats.session_completed {
                    break;
                }
            }
        }

        world.post_segment();
    }

    let completed = world.all_done();
    let duration = world.sim.now.since(SimTime::ZERO);
    let policy = world.policy_name().to_string();
    let (tenants, breakdown, run_records) = world.finish();

    FleetOutcome {
        policy,
        tenants,
        completed,
        duration,
        moved: breakdown.moved,
        client_energy: breakdown.client_energy,
        client_package_energy: breakdown.client_package_energy,
        server_energy: breakdown.server_energy,
        final_active_cores: breakdown.final_active_cores,
        final_freq: breakdown.final_freq,
        hosts: vec![breakdown],
        run_records,
        dead_letters: Vec::new(),
        dead_letter_overflow: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::dataset::standard;

    fn four_tenant_cfg(policy: FleetPolicyKind, seed: u64) -> FleetConfig {
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(policy)).with_seed(seed);
        for i in 0..4u64 {
            cfg.tenants.push(
                TenantSpec::new(
                    format!("tenant-{i}"),
                    standard::medium_dataset(seed + i),
                    AlgorithmKind::MaxThroughput,
                )
                .arriving_at(SimTime::from_secs(20.0 * i as f64)),
            );
        }
        cfg
    }

    #[test]
    fn fleet_run_completes_and_accounts_every_tenant() {
        let out = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 7));
        assert!(out.completed, "all tenants must finish");
        assert_eq!(out.tenants.len(), 4);
        for t in &out.tenants {
            assert!(t.completed, "{} unfinished", t.name);
            assert!(t.moved.as_gb() > 1.0, "{} moved {}", t.name, t.moved);
            assert!(t.attributed_energy.as_joules() > 0.0);
            assert!(t.avg_throughput.as_mbps() > 10.0);
            assert!(t.finished_at.unwrap() > t.arrived_at);
            assert_eq!(t.host, "CloudLab", "single-host fleet serves on the testbed");
        }
        // Attribution is conservative: tenant shares sum to the host bill.
        let attributed: f64 =
            out.tenants.iter().map(|t| t.attributed_energy.as_joules()).sum();
        let host = out.client_energy.as_joules();
        assert!(
            (attributed - host).abs() < 1e-6 * host,
            "attributed {attributed} vs host {host}"
        );
        // The single-host breakdown carries the same totals.
        assert_eq!(out.hosts.len(), 1);
        assert_eq!(out.hosts[0].tenants_served, 4);
        assert_eq!(out.hosts[0].client_energy.as_joules(), host);
        assert_eq!(out.hosts[0].moved.as_f64(), out.moved.as_f64());
    }

    #[test]
    fn fleet_deterministic_given_seed() {
        let a = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 123));
        let b = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 123));
        assert_eq!(a.duration.as_secs(), b.duration.as_secs());
        assert_eq!(a.client_energy.as_joules(), b.client_energy.as_joules());
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                x.attributed_energy.as_joules(),
                y.attributed_energy.as_joules(),
                "{} energy must be reproducible",
                x.name
            );
            assert_eq!(x.finished_at.unwrap().as_secs(), y.finished_at.unwrap().as_secs());
        }
        // And a different seed perturbs the background traffic.
        let c = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 124));
        assert_ne!(a.client_energy.as_joules(), c.client_energy.as_joules());
    }

    #[test]
    fn contended_fleet_is_reproducible_and_slower() {
        let contended = || {
            four_tenant_cfg(FleetPolicyKind::FairShare, 19).with_cross_traffic(
                CrossTrafficConfig {
                    udp_fraction: 0.15,
                    tcp_rate_per_sec: 0.5,
                    tcp_burst_bytes: 25e6,
                    tcp_burst_secs: 1.0,
                },
            )
        };
        let a = run_fleet(&contended());
        let b = run_fleet(&contended());
        assert!(a.completed, "contended fleet must still finish");
        assert_eq!(a.duration.as_secs().to_bits(), b.duration.as_secs().to_bits());
        assert_eq!(
            a.client_energy.as_joules().to_bits(),
            b.client_energy.as_joules().to_bits()
        );
        for (x, y) in a.tenants.iter().zip(&b.tenants) {
            assert_eq!(
                x.finished_at.map(|t| t.as_secs().to_bits()),
                y.finished_at.map(|t| t.as_secs().to_bits()),
                "{}: contended finish time must be seed-reproducible",
                x.name
            );
        }
        // The generators steal real bandwidth: the same workload takes
        // longer than on the quiet path.
        let quiet = run_fleet(&four_tenant_cfg(FleetPolicyKind::FairShare, 19));
        assert!(
            a.duration.as_secs() > quiet.duration.as_secs(),
            "cross-traffic must slow the fleet: {} vs {}",
            a.duration,
            quiet.duration
        );
    }

    #[test]
    fn aimd_fleet_completes_and_is_reproducible() {
        let mk = || four_tenant_cfg(FleetPolicyKind::FairShare, 23).with_aimd(true);
        let a = run_fleet(&mk());
        let b = run_fleet(&mk());
        assert!(a.completed, "AIMD fleet must finish");
        assert_eq!(a.duration.as_secs().to_bits(), b.duration.as_secs().to_bits());
        assert_eq!(
            a.client_energy.as_joules().to_bits(),
            b.client_energy.as_joules().to_bits()
        );
    }

    #[test]
    fn warm_batched_fleet_matches_reference_bit_for_bit() {
        // Constant-background fleet: warm epochs batch in run_fleet's
        // inner loop; every figure must still carry the reference
        // stepper's exact bits.
        let mk = |reference: bool| {
            let mut cfg = four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 17);
            cfg.constant_bg = true;
            cfg.reference_stepper = reference;
            cfg
        };
        let fast = run_fleet(&mk(false));
        let naive = run_fleet(&mk(true));
        assert!(naive.completed, "reference fleet must finish");
        assert_eq!(fast.duration.as_secs().to_bits(), naive.duration.as_secs().to_bits());
        assert_eq!(fast.moved.as_f64().to_bits(), naive.moved.as_f64().to_bits());
        assert_eq!(
            fast.client_energy.as_joules().to_bits(),
            naive.client_energy.as_joules().to_bits()
        );
        assert_eq!(
            fast.server_energy.as_joules().to_bits(),
            naive.server_energy.as_joules().to_bits()
        );
        for (f, n) in fast.tenants.iter().zip(&naive.tenants) {
            assert_eq!(
                f.finished_at.map(|x| x.as_secs().to_bits()),
                n.finished_at.map(|x| x.as_secs().to_bits()),
                "{}: finish time",
                f.name
            );
        }
    }

    #[test]
    fn min_energy_fleet_beats_fair_share_on_energy() {
        // The whole point of the fleet policy: tracking aggregate load
        // burns less host energy than pinning the performance governor.
        let eco = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 9));
        let perf = run_fleet(&four_tenant_cfg(FleetPolicyKind::FairShare, 9));
        assert!(eco.completed && perf.completed);
        assert!(
            eco.client_energy.as_joules() < 0.9 * perf.client_energy.as_joules(),
            "fleet scaling must save energy: {} vs {}",
            eco.client_energy,
            perf.client_energy
        );
    }

    #[test]
    fn baseline_tenants_cannot_fight_the_policy() {
        // curl's built-in ondemand governor actuates only its shadow CPU;
        // the policy-owned host setting must stay where FairShare pinned
        // it (performance: max cores, max frequency) for the whole run.
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(4);
        for i in 0..2u64 {
            cfg.tenants.push(TenantSpec::new(
                format!("t{i}"),
                standard::medium_dataset(4 + i),
                AlgorithmKind::Curl,
            ));
        }
        let out = run_fleet(&cfg);
        assert!(out.completed);
        let spec = testbeds::cloudlab().client_cpu;
        assert_eq!(out.final_active_cores, spec.num_cores);
        assert!(
            (out.final_freq.as_ghz() - spec.max_freq().as_ghz()).abs() < 1e-9,
            "host frequency moved to {} despite the policy owning it",
            out.final_freq
        );
    }

    #[test]
    fn late_arrivals_wait_for_admission() {
        let cfg = four_tenant_cfg(FleetPolicyKind::FairShare, 5);
        let out = run_fleet(&cfg);
        for (i, t) in out.tenants.iter().enumerate() {
            assert!((t.arrived_at.as_secs() - 20.0 * i as f64).abs() < 1e-9);
            assert!(
                t.finished_at.unwrap().as_secs() >= t.arrived_at.as_secs(),
                "{} finished before arriving",
                t.name
            );
        }
    }

    #[test]
    fn per_session_cap_bounds_channels() {
        // 4 tenants under the default 48-channel budget: while all four
        // are resident, nobody may exceed 48/4 = 12 channels once the
        // first arbitration has run (departures later raise the cap).
        let mut cfg = FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::FairShare))
            .with_seed(11);
        for i in 0..4u64 {
            cfg.tenants.push(TenantSpec::new(
                format!("tenant-{i}"),
                standard::medium_dataset(11 + i),
                AlgorithmKind::MaxThroughput,
            ));
        }
        cfg.record_timeline = true;
        let out = run_fleet(&cfg);
        let first_exit = out
            .tenants
            .iter()
            .map(|t| t.finished_at.unwrap().as_secs())
            .fold(f64::MAX, f64::min);
        for t in &out.tenants {
            for p in &t.timeline {
                // Points record the state *before* that timeout's tuning
                // step; the cap from the first arbitration (t=3 s) is
                // visible from the second point on.
                if p.t_secs >= 6.0 - 1e-9 && p.t_secs < first_exit {
                    assert!(
                        p.channels <= 12,
                        "{} ran {} channels at t={} under a fair-share cap",
                        t.name,
                        p.channels,
                        p.t_secs
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_share_gives_the_heavy_tenant_the_channels() {
        // One 27.85 GB tenant next to a 1.94 GB one under WeightedShare:
        // once the first arbitration has split the budget by remaining
        // bytes, the heavy tenant must hold strictly more channels than
        // the light one at every comparable timeline instant.
        let mut cfg =
            FleetConfig::new(testbeds::cloudlab(), Some(FleetPolicyKind::WeightedShare))
                .with_seed(13);
        cfg.tenants.push(TenantSpec::new(
            "heavy",
            standard::large_dataset(13),
            AlgorithmKind::MaxThroughput,
        ));
        cfg.tenants.push(TenantSpec::new(
            "light",
            standard::small_dataset(14),
            AlgorithmKind::MaxThroughput,
        ));
        cfg.record_timeline = true;
        let out = run_fleet(&cfg);
        assert!(out.completed, "both tenants must finish");
        assert_eq!(out.policy, "weighted-share");
        let heavy = &out.tenants[0];
        let light = &out.tenants[1];
        let light_exit = light.finished_at.unwrap().as_secs();
        let mut compared = 0;
        for (h, l) in heavy.timeline.iter().zip(&light.timeline) {
            // Points record the state before that timeout's tuning step;
            // the first weighted split (t=3 s) is visible from the
            // second point on, while both tenants are still resident.
            if h.t_secs >= 6.0 - 1e-9 && h.t_secs < light_exit {
                assert!(
                    h.channels > l.channels,
                    "heavy {} ch vs light {} ch at t={}",
                    h.channels,
                    l.channels,
                    h.t_secs
                );
                compared += 1;
            }
        }
        assert!(compared >= 2, "the overlap must cover comparable points");
    }

    #[test]
    fn completed_tenants_produce_history_records() {
        let out = run_fleet(&four_tenant_cfg(FleetPolicyKind::MinEnergyFleet, 31));
        assert!(out.completed);
        assert_eq!(out.run_records.len(), 4, "one record per completed tenant");
        for (r, t) in out.run_records.iter().zip(&out.tenants) {
            assert_eq!(r.session, t.name);
            assert_eq!(r.testbed, "CloudLab");
            assert_eq!(r.algorithm, "eemt");
            assert!(r.completed);
            assert!(r.cores >= 1 && r.channels >= 1 && r.peak_channels >= 1);
            assert!(r.joules > 0.0 && r.j_per_byte > 0.0);
            assert!((r.moved_bytes - t.moved.as_f64()).abs() < 1.0);
            assert!((r.duration_s - t.residency.as_secs()).abs() < 1e-9);
            assert!((r.rtt_s - 0.036).abs() < 1e-9);
            assert_eq!(r.workload.num_files, 5_000);
        }
        // Staggered arrivals overlap: later tenants were admitted into
        // contention, the first into an empty host.
        assert_eq!(out.run_records[0].contention, 0);
        assert!(out.run_records[1].contention >= 1);
    }

    #[test]
    fn jain_index_limits() {
        // Equal shares are perfectly fair.
        assert!((jain_index([5.0, 5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // One participant taking everything scores 1/n.
        assert!((jain_index([9.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
        // Scale invariance: fairness depends on proportions only.
        let a = jain_index([1.0, 2.0, 3.0]);
        let b = jain_index([10.0, 20.0, 30.0]);
        assert!((a - b).abs() < 1e-12);
        assert!(a > 1.0 / 3.0 && a < 1.0);
        // Degenerate inputs are trivially fair.
        assert_eq!(jain_index(Vec::<f64>::new()), 1.0);
        assert_eq!(jain_index([0.0, 0.0]), 1.0);
    }

    #[test]
    fn fleet_outcome_reports_fairness() {
        let out = run_fleet(&four_tenant_cfg(FleetPolicyKind::FairShare, 21));
        let j = out.jain_fairness();
        // Four near-identical tenants under a fair-share policy: goodputs
        // must be close to equal (staggered arrivals skew them a little).
        assert!(j > 0.8 && j <= 1.0 + 1e-12, "fair-share Jain index {j}");
    }
}
