//! End-to-end driver: proves all three layers compose on a real workload.
//!
//!     make artifacts && cargo run --release --example end_to_end
//!
//! Layer 1 (Pallas candidate-scoring kernel) and Layer 2 (JAX predictor
//! model) were AOT-compiled by `make artifacts` into
//! `artifacts/predictor.hlo.txt`. This driver:
//!
//! 1. loads that artifact through the PJRT CPU client (Layer 3's
//!    `runtime`), verifying the compiled model agrees with the pure-Rust
//!    oracle on a live state vector;
//! 2. runs a complete transfer session — the paper's mixed dataset
//!    (25,128 files, ~42 GB) over the DIDCLab testbed — under the
//!    **predictive governor**, which calls the compiled model on every
//!    tuning decision;
//! 3. runs the identical session under the paper's threshold governor
//!    (Algorithm 3) and reports both, demonstrating the whole stack:
//!    Pallas kernel → JAX model → HLO text → PJRT runtime → Rust
//!    coordinator → simulated WAN + DVFS substrate.

use greendt::config::experiment::TunerParams;
use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::predictor::{cpu_grid, demo_state_for_tests, Predictor};
use greendt::sim::session::{run_session, SessionConfig};

fn main() -> anyhow::Result<()> {
    // --- 1. Load + verify the AOT artifact through PJRT. ---------------
    let path = greendt::runtime::default_predictor_path();
    let pjrt = Predictor::from_artifact(&path).map_err(|e| {
        anyhow::anyhow!("{e:#}\nrun `make artifacts` first to build {path}")
    })?;
    let oracle = Predictor::oracle();
    let grid = cpu_grid(&testbeds::didclab().client_cpu, 6);
    let state = demo_state_for_tests();
    let a = pjrt.predict(&grid, &state)?;
    let b = oracle.predict(&grid, &state)?;
    let max_rel = a
        .iter()
        .zip(&b)
        .map(|(x, y)| {
            ((x.energy_j - y.energy_j).abs() / x.energy_j.abs().max(1.0))
                .max((x.tput_bps - y.tput_bps).abs() / x.tput_bps.abs().max(1.0))
        })
        .fold(0.0f64, f64::max);
    println!("[1/3] PJRT artifact loaded from {path}");
    println!("      {} candidates evaluated; max rel. deviation vs oracle {:.2e}", a.len(), max_rel);
    assert!(max_rel < 2e-4, "PJRT and oracle must agree");

    // --- 2. Full transfer under the predictive (PJRT) governor. --------
    let mk = |params: TunerParams| {
        SessionConfig::new(
            testbeds::didclab(),
            standard::mixed_dataset(42),
            AlgorithmKind::MinEnergy,
        )
        .with_params(params)
    };
    let predictive = run_session(&mk(TunerParams::default().predictive()));
    assert!(predictive.completed);
    println!(
        "[2/3] predictive governor : {} in {} — client energy {} ({} cores @ {} at end)",
        predictive.moved,
        predictive.duration,
        predictive.client_energy,
        predictive.final_active_cores,
        predictive.final_freq
    );

    // --- 3. Same session under the paper's threshold governor. ---------
    let threshold = run_session(&mk(TunerParams::default()));
    assert!(threshold.completed);
    println!(
        "[3/3] threshold governor  : {} in {} — client energy {}",
        threshold.moved, threshold.duration, threshold.client_energy
    );

    let delta = 100.0
        * (1.0
            - predictive.client_energy.as_joules() / threshold.client_energy.as_joules());
    println!(
        "\nend-to-end OK: all layers compose; predictive vs threshold energy: {delta:+.1}%"
    );
    Ok(())
}
