//! Acceptance tests for the failure & resilience subsystem (ISSUE 7).
//!
//! Pins the headline invariants:
//!
//! * **byte conservation** — across a scripted host crash every admitted
//!   byte is accounted for: delivered, retried-and-redelivered on a
//!   revived host, or dead-lettered with an explicit remainder;
//! * **recovery pays** — on the shared `benchkit::resilience` fault
//!   script, recovery-on beats recovery-off on goodput at no extra
//!   joules (advisory-driven evacuation gets the victim off the dying
//!   host before the crash);
//! * **determinism** — the whole fault pipeline is bit-for-bit
//!   invariant across dispatcher shard counts, and an inactive
//!   resilience config is bit-for-bit today's dispatcher;
//! * **degenerate fleets stay finite** — an all-failed fleet reports
//!   finite fairness and energy figures, never NaN.

use greendt::benchkit::resilience::{assert_recovery_wins, scenario, summarize};
use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::resilience::{FaultSchedule, ResilienceConfig};
use greendt::sim::dispatcher::{
    run_dispatcher, DispatchOutcome, DispatcherConfig, HostSpec, SessionSpec,
};
use greendt::units::SimTime;

/// One host that dies at `down_at` and (optionally) revives, serving
/// one medium session — the minimal crash-and-retry story.
fn lone_host_cfg(down_at: f64, revive_at: Option<f64>, recovery: bool) -> DispatcherConfig {
    let faults = FaultSchedule::default().with_host_failure(
        0,
        SimTime::from_secs(down_at),
        revive_at.map(SimTime::from_secs),
    );
    let mut resilience = ResilienceConfig::new().with_faults(faults);
    if recovery {
        resilience = resilience.with_recovery();
    }
    DispatcherConfig::new(
        vec![HostSpec::new("lone", testbeds::cloudlab()).with_max_sessions(1)],
        PlacementKind::MarginalEnergy,
    )
    .with_sessions(vec![SessionSpec::new(
        "survivor",
        standard::medium_dataset(501),
        AlgorithmKind::MaxThroughput,
    )])
    .with_seed(71)
    .with_resilience(resilience)
}

#[test]
fn crash_retry_revival_conserves_bytes() {
    let total = standard::medium_dataset(501).total_size().as_f64();
    let out = run_dispatcher(&lone_host_cfg(30.0, Some(120.0), true));
    let fleet = &out.fleet;
    assert!(fleet.completed, "the survivor must finish after the revival");
    assert!(fleet.dead_letters.is_empty() && fleet.dead_letter_overflow == 0);

    // The fault log tells the whole story: death with one session hit,
    // revival with none (the host was emptied by the preemption).
    assert_eq!(out.faults.len(), 2, "got {:?}", out.faults);
    assert_eq!(out.faults[0].kind.id(), "host-down");
    assert!(
        (out.faults[0].t_secs - 30.0).abs() < 0.2,
        "the death fires on the boundary at its instant, got t={}",
        out.faults[0].t_secs
    );
    assert_eq!(out.faults[0].sessions_hit, 1);
    assert_eq!(out.faults[1].kind.id(), "host-up");
    assert_eq!(out.faults[1].sessions_hit, 0);

    // One retry, first attempt, default PenaltyBox backoff.
    assert_eq!(out.retries.len(), 1);
    let r = &out.retries[0];
    assert_eq!((r.session.as_str(), r.from.as_str(), r.attempt), ("survivor", "lone", 1));
    assert_eq!(r.backoff_secs, 10.0, "first attempt waits the base backoff");
    assert_eq!(r.resume_at_secs, r.t_secs + r.backoff_secs);

    // Two residencies under one name: the failed partial run and the
    // completed redelivery, which together conserve the dataset.
    let runs: Vec<_> = fleet.tenants.iter().filter(|t| t.name == "survivor").collect();
    assert_eq!(runs.len(), 2, "partial + redelivered outcome");
    let (partial, redone) = (runs[0], runs[1]);
    assert!(partial.preempted && !partial.completed);
    assert!(redone.completed && !redone.preempted);
    assert!(
        redone.arrived_at.as_secs() >= 119.9,
        "the retry could not land before the revival, got t={}",
        redone.arrived_at.as_secs()
    );
    let delivered = partial.moved.as_f64() + redone.moved.as_f64();
    assert!(
        (delivered - total).abs() < 16.0,
        "byte conservation across the crash: {delivered} vs {total}"
    );
    assert!(
        (r.remaining_bytes - (total - partial.moved.as_f64())).abs() < 16.0,
        "the retry carries exactly the owed bytes"
    );
}

#[test]
fn budget_exhaustion_and_recovery_off_dead_letter_the_loss() {
    let total = standard::medium_dataset(501).total_size().as_f64();
    // Recovery on, zero retry budget: the first failure is terminal,
    // with the budget named as the reason.
    let mut cfg = lone_host_cfg(30.0, Some(120.0), true);
    cfg.resilience = cfg.resilience.with_retry_budget(0);
    let budgeted = run_dispatcher(&cfg);
    // Recovery off entirely: same terminal loss, blamed on the failure.
    let off = run_dispatcher(&lone_host_cfg(30.0, Some(120.0), false));

    for (label, out, reason) in [
        ("zero budget", &budgeted, "retry-budget-exhausted"),
        ("recovery off", &off, "host-failure"),
    ] {
        let fleet = &out.fleet;
        assert!(!fleet.completed, "{label}: a quarantined fleet is not complete");
        assert!(out.retries.is_empty(), "{label}: nothing may retry");
        assert_eq!(fleet.dead_letters.len(), 1, "{label}");
        assert_eq!(fleet.dead_letter_overflow, 0, "{label}");
        let d = &fleet.dead_letters[0];
        assert_eq!(d.session, "survivor", "{label}");
        assert_eq!(d.host, 0, "{label}");
        assert_eq!(d.reason.id(), reason, "{label}");
        assert_eq!(d.attempts, 1, "{label}");
        assert!((d.at_secs - 30.0).abs() < 0.2, "{label}: quarantined at the death");
        // The dead letter's own ledger closes: delivered + owed = total.
        assert!(
            (d.moved_bytes + d.remaining_bytes - total).abs() < 16.0,
            "{label}: {} + {} vs {total}",
            d.moved_bytes,
            d.remaining_bytes
        );
        // And it agrees with the partial residency's accounting.
        let partial = fleet.tenants.iter().find(|t| t.name == "survivor").unwrap();
        assert!((partial.moved.as_f64() - d.moved_bytes).abs() < 1.0, "{label}");
    }
}

#[test]
fn recovery_beats_terminal_loss_on_the_bench_scenario() {
    let off_out = run_dispatcher(&scenario(false));
    let on_out = run_dispatcher(&scenario(true));
    assert_recovery_wins(&summarize(&off_out), &summarize(&on_out));

    // Recovery off: no advisories, no moves — the victim crawls on the
    // degraded host until the crash quarantines it.
    assert!(off_out.advisories.is_empty() && off_out.migrations.is_empty());
    let d = &off_out.fleet.dead_letters[0];
    assert_eq!(d.session, "victim");
    assert_eq!(d.reason.id(), "host-failure");
    // Byte ledger of the lossy run: what the fleet delivered plus what
    // the dead letter still owes is exactly the admitted workload.
    let admitted = standard::medium_dataset(21).total_size().as_f64()
        + standard::large_dataset(22).total_size().as_f64();
    let off_ledger = off_out.fleet.moved.as_f64() + d.remaining_bytes;
    assert!(
        (off_ledger - admitted).abs() < 32.0,
        "every admitted byte accounted for: {off_ledger} vs {admitted}"
    );

    // Recovery on: the health advisory fires after the dwell, the
    // victim evacuates on the advisory (not a policy move), and the
    // fleet delivers the full workload.
    assert!(!on_out.advisories.is_empty(), "the collapse must be noticed");
    let a = &on_out.advisories[0];
    assert_eq!(a.host, 1, "the flaky host is the degraded one");
    assert!(a.observed_bps < 0.5 * a.expected_bps);
    assert_eq!(on_out.migrations.len(), 1, "one evacuation, got {:?}", on_out.migrations);
    let m = &on_out.migrations[0];
    assert_eq!(m.policy, "evacuate");
    assert_eq!((m.from.as_str(), m.to.as_str()), ("flaky", "steady"));
    assert_eq!(m.session, "victim");
    assert!(
        (on_out.fleet.moved.as_f64() - admitted).abs() < 32.0,
        "recovery delivers the full workload"
    );
}

/// A two-host script exercising every pipeline stage: a death that
/// spawns retries, a revival that re-admits one, and a second death
/// that exhausts the budget into a dead letter.
fn gauntlet_cfg(shards: usize) -> DispatcherConfig {
    let faults = FaultSchedule::default()
        .with_host_failure(1, SimTime::from_secs(60.0), Some(SimTime::from_secs(200.0)))
        .with_host_failure(1, SimTime::from_secs(260.0), None);
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(2),
    ];
    let sessions = vec![
        SessionSpec::new("s0", standard::medium_dataset(511), AlgorithmKind::MaxThroughput),
        SessionSpec::new("s1", standard::medium_dataset(512), AlgorithmKind::MaxThroughput),
        SessionSpec::new("s2", standard::medium_dataset(513), AlgorithmKind::MaxThroughput),
    ];
    let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(83)
        .with_resilience(
            ResilienceConfig::new().with_recovery().with_faults(faults).with_retry_budget(1),
        );
    cfg.shards = shards;
    cfg
}

#[test]
fn fault_pipeline_is_bit_invariant_across_shard_counts() {
    let assert_same = |a: &DispatchOutcome, b: &DispatchOutcome, label: &str| {
        assert_eq!(
            a.fleet.client_energy.as_joules().to_bits(),
            b.fleet.client_energy.as_joules().to_bits(),
            "{label}: fleet energy"
        );
        assert_eq!(
            a.fleet.duration.as_secs().to_bits(),
            b.fleet.duration.as_secs().to_bits(),
            "{label}: makespan"
        );
        assert_eq!(a.fleet.completed, b.fleet.completed, "{label}");
        assert_eq!(a.decisions.len(), b.decisions.len(), "{label}: decisions");
        for (x, y) in a.decisions.iter().zip(&b.decisions) {
            assert_eq!(x.session, y.session, "{label}");
            assert_eq!(x.admitted_host, y.admitted_host, "{label}");
        }
        assert_eq!(a.faults.len(), b.faults.len(), "{label}: faults");
        for (x, y) in a.faults.iter().zip(&b.faults) {
            assert_eq!(x.t_secs.to_bits(), y.t_secs.to_bits(), "{label}");
            assert_eq!((x.host, x.kind, x.sessions_hit), (y.host, y.kind, y.sessions_hit));
        }
        assert_eq!(a.retries.len(), b.retries.len(), "{label}: retries");
        for (x, y) in a.retries.iter().zip(&b.retries) {
            assert_eq!(x.session, y.session, "{label}");
            assert_eq!(x.t_secs.to_bits(), y.t_secs.to_bits(), "{label}");
            assert_eq!(x.remaining_bytes.to_bits(), y.remaining_bytes.to_bits(), "{label}");
        }
        assert_eq!(a.fleet.dead_letters.len(), b.fleet.dead_letters.len(), "{label}");
        for (x, y) in a.fleet.dead_letters.iter().zip(&b.fleet.dead_letters) {
            assert_eq!(x, y, "{label}: dead letters");
        }
        assert_eq!(a.advisories.len(), b.advisories.len(), "{label}: advisories");
        assert_eq!(a.migrations.len(), b.migrations.len(), "{label}: migrations");
    };

    let reference = run_dispatcher(&gauntlet_cfg(1));
    // The gauntlet actually exercises the pipeline end to end.
    assert!(reference.retries.len() >= 2, "both legacy sessions retry");
    assert_eq!(reference.fleet.dead_letters.len(), 1, "the second death exhausts one budget");
    assert!(!reference.fleet.completed);
    let d = &reference.fleet.dead_letters[0];
    assert_eq!(d.attempts, 2);
    assert_eq!(d.reason.id(), "retry-budget-exhausted");
    // Multi-residency ledger: the dead letter's cumulative delivered
    // bytes plus its remainder cover the session's whole dataset.
    let total = standard::medium_dataset(match d.session.as_str() {
        "s0" => 511,
        "s1" => 512,
        _ => 513,
    })
    .total_size()
    .as_f64();
    assert!(
        (d.moved_bytes + d.remaining_bytes - total).abs() < 32.0,
        "ledger closes across residencies: {} + {} vs {total}",
        d.moved_bytes,
        d.remaining_bytes
    );

    for shards in [2usize, 8] {
        let other = run_dispatcher(&gauntlet_cfg(shards));
        assert_same(&reference, &other, &format!("shards={shards}"));
    }
}

#[test]
fn inactive_resilience_is_bit_identical_to_todays_dispatcher() {
    let mk = || {
        let hosts = vec![
            HostSpec::new("efficient", testbeds::cloudlab()),
            HostSpec::new("legacy", testbeds::didclab()),
        ];
        let sessions = vec![
            SessionSpec::new("a", standard::medium_dataset(521), AlgorithmKind::MaxThroughput),
            SessionSpec::new("b", standard::medium_dataset(522), AlgorithmKind::MaxThroughput)
                .arriving_at(SimTime::from_secs(20.0)),
        ];
        DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
            .with_sessions(sessions)
            .with_seed(97)
    };
    let baseline = run_dispatcher(&mk());

    // An explicit default config (the `--resilience off` path) and a
    // recovery-enabled config with no faults to act on: both must match
    // the baseline to the bit — arming the pipeline may not perturb a
    // single tick of a fault-free run.
    let explicit_off = run_dispatcher(&mk().with_resilience(ResilienceConfig::new()));
    let armed_idle =
        run_dispatcher(&mk().with_resilience(ResilienceConfig::new().with_recovery()));

    for (label, other) in [("explicit off", &explicit_off), ("armed, no faults", &armed_idle)] {
        assert!(other.faults.is_empty() && other.retries.is_empty(), "{label}");
        assert!(other.advisories.is_empty(), "{label}: healthy fleet, no advisories");
        assert!(other.fleet.dead_letters.is_empty(), "{label}");
        assert_eq!(
            baseline.fleet.client_energy.as_joules().to_bits(),
            other.fleet.client_energy.as_joules().to_bits(),
            "{label}: fleet energy must be bit-identical"
        );
        assert_eq!(
            baseline.fleet.duration.as_secs().to_bits(),
            other.fleet.duration.as_secs().to_bits(),
            "{label}: makespan must be bit-identical"
        );
        assert_eq!(baseline.decisions.len(), other.decisions.len(), "{label}");
        for (x, y) in baseline.decisions.iter().zip(&other.decisions) {
            assert_eq!(x.session, y.session, "{label}");
            assert_eq!(x.admitted_host, y.admitted_host, "{label}");
            assert_eq!(
                x.projected_fleet_power_w.to_bits(),
                y.projected_fleet_power_w.to_bits(),
                "{label}"
            );
        }
        for (x, y) in baseline.fleet.tenants.iter().zip(&other.fleet.tenants) {
            assert_eq!(x.host, y.host, "{label}: same placements");
            assert_eq!(
                x.attributed_energy.as_joules().to_bits(),
                y.attributed_energy.as_joules().to_bits(),
                "{label}: per-tenant energy"
            );
        }
    }
}

#[test]
fn all_failed_fleet_reports_finite_summaries() {
    // Mid-flight loss: the host dies under its only session with
    // recovery off — everything the fleet ever ran is quarantined.
    let lost = run_dispatcher(&lone_host_cfg(15.0, None, false));
    assert!(!lost.fleet.completed);
    assert_eq!(lost.fleet.dead_letters.len(), 1);
    assert!(lost.fleet.jain_fairness().is_finite());
    assert!(lost.fleet.energy_per_tenant().as_joules().is_finite());
    assert!(!lost.fleet.moved.as_f64().is_nan());

    // Death before anything is admitted: the dispatcher ends an
    // unservable run immediately, with the workload unplaced and every
    // summary still finite.
    let stillborn = run_dispatcher(&lone_host_cfg(0.0, None, false));
    assert!(!stillborn.fleet.completed);
    assert_eq!(stillborn.unplaced, vec!["survivor".to_string()]);
    assert!(stillborn.fleet.tenants.is_empty());
    assert!(stillborn.fleet.jain_fairness().is_finite());
    assert!(stillborn.fleet.energy_per_tenant().as_joules().is_finite());
    assert_eq!(stillborn.fleet.moved.as_f64(), 0.0);
}
