//! Tiny argument parser: positionals, `--key value` flags, `--switch`es.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
/// A command-line parsing/validation error.
pub struct ArgError(pub String);

impl fmt::Display for ArgError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for ArgError {}

/// Parsed command-line arguments.
#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    /// Positional (non-flag) arguments, in order.
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: BTreeSet<String>,
}

impl ParsedArgs {
    /// Parse `argv` (without the program name). `known_switches` take no
    /// value; every other `--name` consumes the next token as its value.
    pub fn parse(argv: &[String], known_switches: &[&str]) -> Result<ParsedArgs, ArgError> {
        let mut out = ParsedArgs::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err(ArgError("bare '--' is not supported".into()));
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if known_switches.contains(&name) {
                    out.switches.insert(name.to_string());
                } else {
                    i += 1;
                    let v = argv.get(i).ok_or_else(|| {
                        ArgError(format!("flag --{name} expects a value"))
                    })?;
                    out.flags.insert(name.to_string(), v.clone());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    /// The value of flag `--name`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// The value of flag `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Parse flag `--name` as a float.
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, ArgError> {
        self.get(name)
            .map(|v| v.parse::<f64>().map_err(|_| ArgError(format!("--{name}: bad number '{v}'"))))
            .transpose()
    }

    /// Parse flag `--name` as an unsigned integer.
    pub fn get_u64(&self, name: &str) -> Result<Option<u64>, ArgError> {
        self.get(name)
            .map(|v| v.parse::<u64>().map_err(|_| ArgError(format!("--{name}: bad integer '{v}'"))))
            .transpose()
    }

    /// Parse flag `--name` as a `u32`.
    pub fn get_u32(&self, name: &str) -> Result<Option<u32>, ArgError> {
        match self.get_u64(name)? {
            Some(v) => u32::try_from(v)
                .map(Some)
                .map_err(|_| ArgError(format!("--{name}: value '{v}' out of range"))),
            None => Ok(None),
        }
    }

    /// True when the switch was given.
    pub fn has(&self, switch: &str) -> bool {
        self.switches.contains(switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixture() {
        let a = ParsedArgs::parse(&argv("run --testbed didclab --trace --seed 7"), &["trace"])
            .unwrap();
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.get("testbed"), Some("didclab"));
        assert!(a.has("trace"));
        assert_eq!(a.get_u64("seed").unwrap(), Some(7));
        assert_eq!(a.get_u32("seed").unwrap(), Some(7));
    }

    #[test]
    fn equals_form() {
        let a = ParsedArgs::parse(&argv("--target-mbps=400"), &[]).unwrap();
        assert_eq!(a.get_f64("target-mbps").unwrap(), Some(400.0));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(ParsedArgs::parse(&argv("--testbed"), &[]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = ParsedArgs::parse(&argv("--seed x"), &[]).unwrap();
        assert!(a.get_u64("seed").is_err());
    }

    #[test]
    fn defaults() {
        let a = ParsedArgs::parse(&argv(""), &[]).unwrap();
        assert_eq!(a.get_or("dataset", "mixed"), "mixed");
        assert!(!a.has("trace"));
    }
}
