//! Acceptance tests for the analysis half of the observability stack
//! (ISSUE 10): the structural trace differ and the decision calibration
//! ledger.
//!
//! * **shard-invariant diff** — `trace diff` of a 1-shard and an
//!   8-shard run of the same `(config, seed)` is empty (the differ
//!   agrees with the byte-identity contract in `trace_determinism`);
//! * **fault localization** — diffing a `--resilience on` run against
//!   the `off` run of the same faulted workload confines every
//!   per-session delta to the session the fault actually hit, and the
//!   on-side surplus names the recovery machinery (retry, penalty box);
//! * **ledger reconciliation** — calibration records join 1:1 with
//!   `FleetOutcome` tenants by (session, host) and match their realized
//!   bytes/joules to the bit, migrations included.

use greendt::config::testbeds;
use greendt::coordinator::{AlgorithmKind, PlacementKind};
use greendt::dataset::standard;
use greendt::obs::{trace_jsonl, TraceDiff, TraceLog};
use greendt::rebalance::{RebalanceConfig, RebalancePolicyKind};
use greendt::resilience::{FaultSchedule, ResilienceConfig};
use greendt::sim::dispatcher::{run_dispatcher, DispatcherConfig, HostSpec, SessionSpec};
use greendt::units::SimTime;

/// The busy heterogeneous fleet from `trace_determinism`: five hosts,
/// eight staggered sessions, enough churn to cross many segments.
fn busy_cfg(shards: usize) -> DispatcherConfig {
    let testbeds = testbeds::all();
    let hosts: Vec<HostSpec> = (0..5)
        .map(|i| {
            let tb = testbeds[i % testbeds.len()].clone();
            HostSpec::new(format!("host{i}-{}", tb.name), tb).with_max_sessions(2)
        })
        .collect();
    let sessions: Vec<SessionSpec> = (0..8u64)
        .map(|i| {
            SessionSpec::new(
                format!("session-{i}"),
                standard::medium_dataset(100 + i),
                if i % 2 == 0 { AlgorithmKind::MaxThroughput } else { AlgorithmKind::MinEnergy },
            )
            .arriving_at(SimTime::from_secs(10.0 * i as f64))
        })
        .collect();
    DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(7)
        .with_shards(shards)
        .with_trace()
        .with_metrics()
}

#[test]
fn diff_of_shard_counts_is_empty() {
    let a = run_dispatcher(&busy_cfg(1));
    let b = run_dispatcher(&busy_cfg(8));
    let log_a = TraceLog::parse(&trace_jsonl(a.trace.as_ref().unwrap()));
    let log_b = TraceLog::parse(&trace_jsonl(b.trace.as_ref().unwrap()));
    assert!(!log_a.records.is_empty(), "the busy fleet must trace something");
    let diff = TraceDiff::compute(&log_a, &log_b);
    assert!(
        diff.is_empty(),
        "1-shard vs 8-shard logs must diff empty:\n{}",
        diff.to_markdown("shards=1", "shards=8")
    );
    // And the diff of a log against itself is trivially empty too.
    assert!(TraceDiff::compute(&log_a, &log_a).is_empty());
}

/// Two single-slot hosts, one session each, so placement is forced and
/// the scripted death of host 1 hits exactly `session-1`.
fn pair_cfg(faults: Option<FaultSchedule>, recovery: bool) -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("host-a", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("host-b", testbeds::cloudlab()).with_max_sessions(1),
    ];
    let sessions = vec![
        SessionSpec::new("session-0", standard::medium_dataset(11), AlgorithmKind::MaxThroughput),
        SessionSpec::new("session-1", standard::medium_dataset(12), AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(1.0)),
    ];
    let mut cfg = DispatcherConfig::new(hosts, PlacementKind::LeastLoaded)
        .with_sessions(sessions)
        .with_seed(13)
        .with_trace()
        .with_metrics();
    if let Some(f) = faults {
        let mut res = ResilienceConfig::new().with_faults(f);
        if recovery {
            res = res.with_recovery();
        }
        cfg.resilience = res;
    }
    cfg
}

#[test]
fn resilience_diff_localizes_to_the_faulted_session() {
    // Probe (no faults): learn when session-1 finishes so the scripted
    // death lands mid-residency.
    let probe = run_dispatcher(&pair_cfg(None, false));
    assert!(probe.fleet.completed);
    let finish = probe
        .fleet
        .tenants
        .iter()
        .find(|t| t.name == "session-1")
        .and_then(|t| t.finished_at)
        .expect("session-1 finishes in the probe")
        .as_secs();
    let down = (1.0 + finish) / 2.0;
    let faults = || {
        FaultSchedule::default().with_host_failure(
            1,
            SimTime::from_secs(down),
            Some(SimTime::from_secs(finish + 200.0)),
        )
    };

    let off = run_dispatcher(&pair_cfg(Some(faults()), false));
    let on = run_dispatcher(&pair_cfg(Some(faults()), true));
    assert!(!off.fleet.completed, "without recovery the loss is terminal");
    assert!(on.fleet.completed, "recovery must redeliver session-1");
    assert!(on.retries.iter().any(|r| r.session == "session-1"));

    let log_off = TraceLog::parse(&trace_jsonl(off.trace.as_ref().unwrap()));
    let log_on = TraceLog::parse(&trace_jsonl(on.trace.as_ref().unwrap()));
    let diff = TraceDiff::compute(&log_off, &log_on);
    assert!(!diff.is_empty(), "the recovery switch must change the trace");

    // Every sessioned delta — missing records, surplus records, tally
    // drift — belongs to the session the fault hit. session-0's story
    // is untouched by the recovery machinery.
    for d in diff.only_in_a.iter().chain(&diff.only_in_b) {
        if let Some(s) = &d.session {
            assert_eq!(s, "session-1", "delta leaked outside the faulted session: {}", d.record);
        }
    }
    for d in &diff.session_deltas {
        assert_eq!(d.session, "session-1", "tally drift outside the faulted session");
    }
    assert!(diff.sessions_only_in_a.is_empty() && diff.sessions_only_in_b.is_empty());

    // The on-side surplus is the recovery machinery by name.
    let on_names: Vec<&str> = diff.only_in_b.iter().map(|d| d.name.as_str()).collect();
    for expected in ["retry", "penalty_box"] {
        assert!(on_names.contains(&expected), "on-side lacks '{expected}': {on_names:?}");
    }
    // The off-side surplus contains the terminal dead-letter.
    assert!(
        diff.only_in_a.iter().any(|d| d.name == "dead_letter"),
        "off-side must dead-letter the lost session"
    );
}

/// The hot-spot scenario from `trace_determinism`: the marginal-delta
/// rebalancer migrates s1 off the legacy host, so the ledger sees a
/// preempt-closed residency, a migration join, and completions.
fn hotspot_cfg() -> DispatcherConfig {
    let hosts = vec![
        HostSpec::new("efficient", testbeds::cloudlab()).with_max_sessions(1),
        HostSpec::new("legacy", testbeds::didclab()).with_max_sessions(4),
    ];
    let sessions = vec![
        SessionSpec::new("s0", standard::medium_dataset(301), AlgorithmKind::MaxThroughput),
        SessionSpec::new("s1", standard::large_dataset(302), AlgorithmKind::MaxThroughput)
            .arriving_at(SimTime::from_secs(5.0)),
    ];
    let mut cfg = DispatcherConfig::new(hosts, PlacementKind::MarginalEnergy)
        .with_sessions(sessions)
        .with_seed(61)
        .with_trace()
        .with_metrics();
    cfg.rebalance = RebalanceConfig::new(RebalancePolicyKind::MarginalEnergyDelta);
    cfg
}

#[test]
fn calibration_ledger_reconciles_to_the_bit() {
    let out = run_dispatcher(&hotspot_cfg());
    assert!(out.fleet.completed);
    assert!(
        out.migrations.iter().any(|m| m.session == "s1"),
        "the hot-spot scenario must migrate s1"
    );
    let cal = out.calibration.as_ref().expect("observability turns the ledger on");

    // One calibration record per residency, joined 1:1 against the
    // tenant outcomes by (session, host) — the migration means s1 has
    // two residencies on two hosts, and both must reconcile.
    assert_eq!(cal.placements.len(), out.fleet.tenants.len(), "one record per residency");
    for rec in &cal.placements {
        let tenant = out
            .fleet
            .tenants
            .iter()
            .find(|t| t.name == rec.session && t.host == rec.host)
            .unwrap_or_else(|| panic!("no tenant outcome for {}@{}", rec.session, rec.host));
        assert_eq!(
            rec.realized_bytes.to_bits(),
            tenant.moved.as_f64().to_bits(),
            "{}@{}: realized bytes",
            rec.session,
            rec.host
        );
        assert_eq!(
            rec.realized_joules.to_bits(),
            tenant.attributed_energy.as_joules().to_bits(),
            "{}@{}: realized joules",
            rec.session,
            rec.host
        );
        assert_eq!(
            rec.end == "preempt",
            tenant.preempted,
            "{}@{}: end kind agrees with the outcome",
            rec.session,
            rec.host
        );
    }

    // The fleet-level sums bit-match too (per-host accumulation order
    // is the same on both sides).
    let ledger_joules: f64 = cal.placements.iter().map(|r| r.realized_joules).sum();
    let fleet_joules: f64 =
        out.fleet.tenants.iter().map(|t| t.attributed_energy.as_joules()).sum();
    assert_eq!(
        cal.realized_joules().to_bits(),
        ledger_joules.to_bits(),
        "ledger sum accessor agrees with a manual fold"
    );
    // Order-insensitive check against the outcome side: same multiset
    // of per-residency joules ⇒ compare sorted folds.
    let mut a: Vec<f64> = cal.placements.iter().map(|r| r.realized_joules).collect();
    let mut b: Vec<f64> =
        out.fleet.tenants.iter().map(|t| t.attributed_energy.as_joules()).collect();
    a.sort_by(f64::total_cmp);
    b.sort_by(f64::total_cmp);
    let (sa, sb) = (a.iter().sum::<f64>(), b.iter().sum::<f64>());
    assert_eq!(sa.to_bits(), sb.to_bits(), "summed realized joules bit-match");
    assert!(fleet_joules.is_finite());

    // The migration joined: the preempt-side and resume-side
    // residencies produced a realized delay and a realized benefit.
    let mig = cal
        .migrations
        .iter()
        .find(|m| m.session == "s1")
        .expect("the ledger records the migration");
    assert!(mig.realized_delay_s.is_some(), "migration joined to its resumed residency");
    assert!(mig.realized_benefit_j.is_some());
    assert!(mig.realized_delay_s.unwrap() >= 0.0);

    // Metrics agree with the ledger's counts.
    let m = out.metrics.as_ref().unwrap();
    assert_eq!(m.registry.counter("calibration.records"), cal.placements.len() as u64);
    assert_eq!(m.registry.counter("calibration.anomalies"), cal.anomalies.len() as u64);
}
