//! Failure injection: a competing flow takes half the path mid-transfer.
//!
//!     cargo run --release --example adaptive_bandwidth
//!
//! At t = 30 s a scripted event raises the background-traffic mean from
//! 8 % to 55 % of the CloudLab bottleneck; at t = 90 s it clears. The
//! timeline shows EEMT's finite state machine (Figure 1) riding through
//! it: the throughput reference drops, Warning/Recovery probe whether the
//! loss is channel-induced or path-induced, and the channel count is
//! restored once capacity returns.

use greendt::config::testbeds;
use greendt::coordinator::AlgorithmKind;
use greendt::dataset::standard;
use greendt::netsim::BandwidthEvent;
use greendt::sim::session::{run_session, SessionConfig};
use greendt::units::SimTime;

fn main() {
    let cfg = SessionConfig::new(
        testbeds::cloudlab(),
        standard::large_dataset(42),
        AlgorithmKind::MaxThroughput,
    )
    .with_bandwidth_events(vec![
        BandwidthEvent { at: SimTime::from_secs(30.0), mean_fraction: 0.55 },
        BandwidthEvent { at: SimTime::from_secs(90.0), mean_fraction: 0.08 },
    ])
    .recording();

    let out = run_session(&cfg);
    assert!(out.completed);

    println!("adaptive bandwidth — EEMT on CloudLab, 28 GB large dataset");
    println!("background flow: +47% of the pipe at t=30s, gone at t=90s\n");
    println!("  t(s)   throughput   channels  cores  power");
    for p in &out.timeline {
        let marker = if (30.0..90.0).contains(&p.t_secs) { "<< congested" } else { "" };
        println!(
            "  {:>5.0}  {:>11}  {:>8}  {:>5}  {:>5.1} W  {}",
            p.t_secs,
            format!("{}", p.throughput),
            p.channels,
            p.active_cores,
            p.power_w,
            marker
        );
    }
    println!("\n  total: {} in {} ({}); client energy {}",
        out.moved, out.duration, out.avg_throughput, out.client_energy);
}
