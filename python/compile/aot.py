"""AOT pipeline: lower the Layer-2 predictor to HLO text for the Rust side.

HLO *text* (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/README.md.

Usage:  python -m compile.aot --out ../artifacts/predictor.hlo.txt
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-compatible path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_predictor() -> str:
    lowered = jax.jit(model.predict).lower(*model.example_args())
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out",
        default="../artifacts/predictor.hlo.txt",
        help="output path for the HLO text artifact",
    )
    args = ap.parse_args()

    text = lower_predictor()
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(text)
    print(f"wrote {len(text)} chars of HLO to {args.out}")

    # Smoke-check the lowered function agrees with the oracle on demo data.
    import numpy as np

    got = np.asarray(model.predict(model.demo_grid(), model.demo_state()))
    want = np.asarray(model.predict_reference(model.demo_grid(), model.demo_state()))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=1e-3)
    print("kernel vs oracle: OK")


if __name__ == "__main__":
    main()
