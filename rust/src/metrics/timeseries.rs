//! Session timeline export.

use crate::sim::session::{SessionOutcome, TimelinePoint};
use anyhow::{Context, Result};
use std::fmt::Write as _;
use std::path::Path;

/// Render a session's per-timeout timeline as CSV (one row per tuning
/// interval) — the raw material for time-series plots like the paper's
/// FSM walkthroughs.
pub fn timeline_csv(outcome: &SessionOutcome) -> String {
    let mut out = String::from(
        "t_s,fsm,throughput_mbps,channels,active_cores,freq_ghz,cpu_load,power_w\n",
    );
    for p in &outcome.timeline {
        let _ = writeln!(
            out,
            "{:.1},{},{:.1},{},{},{:.2},{:.3},{:.1}",
            p.t_secs,
            p.fsm,
            p.throughput.as_mbps(),
            p.channels,
            p.active_cores,
            p.freq.as_ghz(),
            p.cpu_load,
            p.power_w
        );
    }
    out
}

/// Write the timeline CSV to a file (creating parent directories).
pub fn save_timeline(outcome: &SessionOutcome, path: impl AsRef<Path>) -> Result<()> {
    let path = path.as_ref();
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    }
    std::fs::write(path, timeline_csv(outcome))
        .with_context(|| format!("writing {}", path.display()))
}

/// Aggregate statistics over a timeline slice (plot annotations, tests).
pub fn mean_throughput_mbps(points: &[TimelinePoint]) -> f64 {
    if points.is_empty() {
        return 0.0;
    }
    points.iter().map(|p| p.throughput.as_mbps()).sum::<f64>() / points.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::testbeds;
    use crate::coordinator::AlgorithmKind;
    use crate::dataset::standard;
    use crate::sim::session::{run_session, SessionConfig};

    fn outcome() -> SessionOutcome {
        run_session(
            &SessionConfig::new(
                testbeds::cloudlab(),
                standard::large_dataset(1),
                AlgorithmKind::MaxThroughput,
            )
            .recording(),
        )
    }

    #[test]
    fn csv_has_header_and_rows() {
        let out = outcome();
        let csv = timeline_csv(&out);
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].starts_with("t_s,fsm,throughput_mbps"));
        assert_eq!(lines.len(), out.timeline.len() + 1);
    }

    #[test]
    fn save_round_trips() {
        let out = outcome();
        let path = std::env::temp_dir().join("greendt_tl_test/tl.csv");
        save_timeline(&out, &path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), timeline_csv(&out));
        let _ = std::fs::remove_dir_all(path.parent().unwrap());
    }

    #[test]
    fn mean_matches_hand_computation() {
        let out = outcome();
        let m = mean_throughput_mbps(&out.timeline);
        assert!(m > 0.0);
        assert_eq!(mean_throughput_mbps(&[]), 0.0);
    }
}
