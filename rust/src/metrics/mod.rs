//! Result tables, summary statistics and file output.

mod stats;
mod table;
pub mod timeseries;

pub use stats::Summary;
pub use table::Table;
