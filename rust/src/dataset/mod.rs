//! Datasets, file partitioning, and BDP-based chunking.
//!
//! Mirrors §II and Algorithm 1 of the paper: a transfer moves a *dataset*
//! (a list of files); the heuristic initializer clusters files into
//! partitions of similar size, splits files larger than the BDP into
//! BDP-sized chunks, and assigns per-partition pipelining levels.
//!
//! [`standard`] provides deterministic generators for the exact datasets of
//! Table II (small / medium / large / mixed).

mod files;
mod generator;
pub mod manifest;
mod partition;
pub mod standard;

pub use files::{Dataset, FileId, FileSpec};
pub use generator::{DatasetSpec, generate};
pub use manifest::{load_manifest, parse_manifest, save_manifest};
pub use partition::{partition_files, partition_files_capped, Partition, PartitionStats};
